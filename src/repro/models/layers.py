"""Core transformer layers: norms, RoPE/M-RoPE, GQA attention (global /
sliding-window, softcap, qk-norm, bias), SwiGLU/GeGLU MLP, embeddings.

All functions are pure; parameters are dicts of arrays, and each ``init_*``
returns ``(params, specs)`` where ``specs`` mirrors the params pytree with
tuples of *logical* axis names consumed by ``ShardingRules``.

Attention uses a dense path for short sequences and a query-block-scanned
online-softmax path (flash-attention structure, pure jnp) for long ones —
the latter keeps peak activation memory bounded for the 32k prefill cells
and keeps the scanned HLO compact.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import AttnSpec, ModelConfig
from repro.sharding.rules import ShardingRules

# Threshold above which attention switches to the query-chunked path.
CHUNKED_ATTN_THRESHOLD = 8192
Q_CHUNK = 1024


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rms_norm(d: int, dtype) -> tuple:
    return {"scale": jnp.zeros((d,), dtype)}, {"scale": (None,)}


def rms_norm(x, params, eps: float = 1e-6):
    """RMSNorm with (1 + scale) parameterization (gemma/llama compatible)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(x.dtype)


def init_layer_norm(d: int, dtype) -> tuple:
    return ({"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)},
            {"scale": (None,), "bias": (None,)})


def layer_norm(x, params, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------

def _rope_angles(positions, dim: int, theta: float):
    """positions [...,] -> (sin, cos) of shape [..., dim/2]."""
    half = dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x, positions, theta: float):
    """x: [B, S, ..., D] (any number of head axes); positions: [B, S]."""
    sin, cos = _rope_angles(positions, x.shape[-1], theta)   # [B, S, D/2]
    expand = (slice(None), slice(None)) + (None,) * (x.ndim - 3)
    sin, cos = sin[expand], cos[expand]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections):
    """Qwen2-VL multimodal RoPE.  positions3: [B, S, 3] (t, h, w ids);
    ``sections`` splits the half-dim across the three id streams.
    x: [B, S, ..., D] (any number of head axes)."""
    b, s = x.shape[:2]
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # choose which positional stream drives each frequency band
    sec_id = jnp.repeat(jnp.arange(3), jnp.asarray(sections),
                        total_repeat_length=half)             # [half]
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),                        # [B, S, 3]
        jnp.broadcast_to(sec_id[None, None, :], (b, s, half)).astype(jnp.int32) % 3,
        axis=2)                                                # [B, S, half]
    ang = pos * freq[None, None, :]
    expand = (slice(None), slice(None)) + (None,) * (x.ndim - 3)
    sin, cos = jnp.sin(ang)[expand], jnp.cos(ang)[expand]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def sinusoidal_embedding(length: int, dim: int):
    pos = np.arange(length)[:, None]
    i = np.arange(dim // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * i / dim)
    emb = np.concatenate([np.sin(ang), np.cos(ang)], axis=1)
    return jnp.asarray(emb, jnp.float32)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, dtype, *, cross: bool = False):
    """Grouped layout: wq/wo carry explicit (kv_heads, q_group) axes so the
    q/o projections can shard over 'model' via EITHER axis — kv_heads when
    it divides the TP width, else the GQA group axis (llama3-405b: kv=8
    cannot shard 16-way, but its group of 16 q-heads per kv head can)."""
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // kv
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std = d ** -0.5
    params = {
        "wq": jax.random.normal(k1, (d, kv, g, hd), dtype) * std,
        "wk": jax.random.normal(k2, (d, kv, hd), dtype) * std,
        "wv": jax.random.normal(k3, (d, kv, hd), dtype) * std,
        "wo": jax.random.normal(k4, (kv, g, hd, d), dtype) * (h * hd) ** -0.5,
    }
    specs = {
        "wq": ("d_model", "kv_heads", "q_group", "head_dim"),
        "wk": ("d_model", "kv_heads", "head_dim"),
        "wv": ("d_model", "kv_heads", "head_dim"),
        "wo": ("kv_heads", "q_group", "head_dim", "d_model"),
    }
    if cfg.attn.qkv_bias:
        params.update(bq=jnp.zeros((kv, g, hd), dtype),
                      bk=jnp.zeros((kv, hd), dtype),
                      bv=jnp.zeros((kv, hd), dtype))
        specs.update(bq=("kv_heads", "q_group", "head_dim"),
                     bk=("kv_heads", "head_dim"),
                     bv=("kv_heads", "head_dim"))
    if cfg.attn.qk_norm:
        params.update(q_norm=jnp.zeros((hd,), dtype),
                      k_norm=jnp.zeros((hd,), dtype))
        specs.update(q_norm=(None,), k_norm=(None,))
    return params, specs


def _softcap(scores, cap: float):
    if cap and cap > 0:
        return jnp.tanh(scores / cap) * cap
    return scores


def _attn_dense(q, k, v, *, causal, window, softcap, q_offset, kv_valid_len,
                scale):
    """q: [B, Sq, KV, G, Dh]; k/v: [B, Sk, KV, Dh].  Mask semantics:
    query global position = q_offset + row; kv position = column index."""
    b, sq, kvh, g, dh = q.shape
    sk = k.shape[1]
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                        preferred_element_type=jnp.float32) * scale
    scores = _softcap(scores, softcap)
    qpos = q_offset + jnp.arange(sq)
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    if kv_valid_len is not None:
        mask &= kpos[None, :] < kv_valid_len
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out


def _attn_chunked(q, k, v, *, causal, window, softcap, scale):
    """Query-block scan with online softmax (flash structure, pure jnp)."""
    b, sq, kvh, g, dh = q.shape
    sk = k.shape[1]
    nblk = sq // Q_CHUNK
    assert sq % Q_CHUNK == 0, (sq, Q_CHUNK)
    qb = q.reshape(b, nblk, Q_CHUNK, kvh, g, dh).transpose(1, 0, 2, 3, 4, 5)

    kpos = jnp.arange(sk)

    def body(_, blk):
        qi, qblk = blk      # qi: scalar block index; qblk [B, C, KV, G, Dh]
        qpos = qi * Q_CHUNK + jnp.arange(Q_CHUNK)
        scores = jnp.einsum("bqkgd,bskd->bkgqs", qblk, k,
                            preferred_element_type=jnp.float32) * scale
        scores = _softcap(scores, softcap)
        mask = jnp.ones((Q_CHUNK, sk), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window:
            mask &= kpos[None, :] > qpos[:, None] - window
        scores = jnp.where(mask[None, None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(qblk.dtype)
        out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
        return None, out

    from repro.models import flags
    _, outs = jax.lax.scan(body, None, (jnp.arange(nblk), qb),
                           unroll=flags.inner_scan_unroll())
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, kvh, g, dh)


def attention(params, x, positions, rules: ShardingRules, cfg: ModelConfig,
              *, kind: str = "global", cache=None, decode_pos=None,
              cross_kv=None, causal: bool = True, rope: bool = True,
              theta_override: Optional[float] = None):
    """Self- or cross-attention with GQA.

    cache: optional dict(k=[B, Sc, KV, Dh], v=..., rolling: bool) — decode
    mode writes the current token at ``decode_pos`` ([B] int32) and attends
    over the cache.
    Returns (out [B, S, d_model], new_cache or None).
    """
    spec: AttnSpec = cfg.attn
    b, s, _ = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // kvh
    window = spec.window if kind == "local" else 0

    q = jnp.einsum("bsd,dkgh->bskgh", x, params["wq"])   # [B,S,KV,G,HD]
    if cross_kv is None:
        k = jnp.einsum("bsd,dkh->bskh", x, params["wk"])
        v = jnp.einsum("bsd,dkh->bskh", x, params["wv"])
    else:
        k = jnp.einsum("bsd,dkh->bskh", cross_kv, params["wk"])
        v = jnp.einsum("bsd,dkh->bskh", cross_kv, params["wv"])
    if spec.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"] if cross_kv is None else k + params["bk"]
        v = v + params["bv"] if cross_kv is None else v + params["bv"]
    if spec.qk_norm:
        q = rms_norm(q, {"scale": params["q_norm"]}, cfg.norm_eps)
        k = rms_norm(k, {"scale": params["k_norm"]}, cfg.norm_eps)
    if rope and spec.rope and cross_kv is None:
        theta = theta_override if theta_override is not None else (
            spec.rope_theta_local
            if (kind == "local" and spec.rope_theta_local) else spec.rope_theta)
        if cfg.mrope and positions.ndim == 3:
            q = apply_mrope(q, positions, theta, cfg.mrope_sections)
            k = apply_mrope(k, positions, theta, cfg.mrope_sections)
        else:
            q = apply_rope(q, positions, theta)
            k = apply_rope(k, positions, theta)
    # Flat-head mode: when neither kv_heads nor the GQA group divides the TP
    # width but the flat head count does (mixtral 48, yi 32, gemma2 16 on
    # tp=16), repeat K/V to full heads and shard the flat head axis — the
    # attention compute and score buffers shard 1/tp instead of being
    # replicated.  Params stay FSDP-sharded; K/V repeat is activation-only.
    # Full-sequence paths only (decode caches stay un-repeated).
    flat = (cache is None and rules.mesh is not None
            and rules.table.get("heads") is not None
            and rules.table.get("kv_heads") is None
            and rules.table.get("q_group") is None
            and h % rules.logical_size("heads") == 0)
    if flat:
        q = q.reshape(b, s, h, 1, hd)
        q = rules.shard(q, "batch", "seq", "heads", None, None)
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
        k = rules.shard(k, "batch", "seq", "heads", None)
        v = rules.shard(v, "batch", "seq", "heads", None)
        kvh_eff, g_eff = h, 1
    else:
        q = rules.shard(q, "batch", "seq", "kv_heads", "q_group", None)
        k = rules.shard(k, "batch", "seq", "kv_heads", None)
        v = rules.shard(v, "batch", "seq", "kv_heads", None)
        kvh_eff, g_eff = kvh, g
    scale = hd ** -0.5
    new_cache = None

    if cache is not None:
        # decode: write token 0 of k/v at decode_pos, attend over cache.
        # A local-attention cache sized exactly to the window is a *rolling*
        # ring buffer (static property — inferred from shapes, so it is not
        # carried as a traced flag through scan).
        sc = cache["k"].shape[1]
        rolling = (kind == "local" and window and sc == window)
        widx = decode_pos % sc if rolling else decode_pos
        bidx = jnp.arange(b)
        ck = cache["k"].at[bidx, widx].set(k[:, 0].astype(cache["k"].dtype))
        cv = cache["v"].at[bidx, widx].set(v[:, 0].astype(cache["v"].dtype))
        new_cache = dict(cache, k=ck, v=cv)
        qh = q
        kpos = jnp.arange(sc)
        if rolling:
            # Slot j holds the most recent position ≡ j (mod sc); once
            # decode_pos >= sc every slot is within the window.  Earlier,
            # only slots <= decode_pos have been written.
            valid = (kpos[None, :] <= decode_pos[:, None]) | (
                decode_pos[:, None] >= sc)
        else:
            valid = kpos[None, :] <= decode_pos[:, None]
            if window:
                valid &= kpos[None, :] > decode_pos[:, None] - window
        scores = jnp.einsum("bqkgd,bskd->bkgqs", qh, ck,
                            preferred_element_type=jnp.float32) * scale
        scores = _softcap(scores, spec.softcap)
        scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = jnp.einsum("bkgqs,bskd->bqkgd", probs, cv)
    else:
        qh = q
        from repro.models import flags
        threshold = flags.ATTN_CHUNK_THRESHOLD or CHUNKED_ATTN_THRESHOLD
        use_chunked = (s >= threshold and s % Q_CHUNK == 0
                       and cross_kv is None)
        if use_chunked:
            out = _attn_chunked(qh, k, v, causal=causal, window=window,
                                softcap=spec.softcap, scale=scale)
        else:
            out = _attn_dense(qh, k, v, causal=causal and cross_kv is None,
                              window=window, softcap=spec.softcap,
                              q_offset=0, kv_valid_len=None, scale=scale)
        if flat:
            out = out.reshape(b, s, kvh, g, hd)
    out = jnp.einsum("bskgh,kghd->bsd", out, params["wo"])
    out = rules.shard(out, "batch", "seq", "act_d_model")
    return out, new_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, d_ff: int, dtype, *, gated: bool = True):
    k1, k2, k3 = jax.random.split(key, 3)
    std_in, std_out = d ** -0.5, d_ff ** -0.5
    if gated:
        params = {
            "wi_gate": jax.random.normal(k1, (d, d_ff), dtype) * std_in,
            "wi_up": jax.random.normal(k2, (d, d_ff), dtype) * std_in,
            "wo": jax.random.normal(k3, (d_ff, d), dtype) * std_out,
        }
        specs = {"wi_gate": ("d_model", "d_ff"), "wi_up": ("d_model", "d_ff"),
                 "wo": ("d_ff", "d_model")}
    else:
        params = {
            "wi": jax.random.normal(k1, (d, d_ff), dtype) * std_in,
            "wo": jax.random.normal(k3, (d_ff, d), dtype) * std_out,
        }
        specs = {"wi": ("d_model", "d_ff"), "wo": ("d_ff", "d_model")}
    return params, specs


def _act(x, kind: str):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(kind)


def mlp(params, x, act: str, rules: ShardingRules):
    if "wi_gate" in params:
        hidden = _act(jnp.einsum("bsd,df->bsf", x, params["wi_gate"]), act) \
            * jnp.einsum("bsd,df->bsf", x, params["wi_up"])
    else:
        hidden = _act(jnp.einsum("bsd,df->bsf", x, params["wi"]), act)
    hidden = rules.shard(hidden, "batch", "seq", "d_ff")
    out = jnp.einsum("bsf,fd->bsd", hidden, params["wo"])
    return rules.shard(out, "batch", "seq", "act_d_model")


# ---------------------------------------------------------------------------
# Embedding / unembedding (vocab padded for clean sharding — production
# practice and required for e.g. whisper's 51865 on a 16-wide axis)
# ---------------------------------------------------------------------------

def padded_vocab(cfg: ModelConfig, multiple: int = 2048) -> int:
    return -(-cfg.vocab_size // multiple) * multiple


def init_embedding(key, cfg: ModelConfig, dtype):
    pv = padded_vocab(cfg)
    params = {"table": jax.random.normal(key, (pv, cfg.d_model), dtype)
              * cfg.d_model ** -0.5}
    specs = {"table": ("vocab", "d_model")}
    if not cfg.tie_embeddings:
        k2 = jax.random.fold_in(key, 1)
        params["unembed"] = jax.random.normal(
            k2, (cfg.d_model, pv), dtype) * cfg.d_model ** -0.5
        specs["unembed"] = ("d_model", "vocab")
    return params, specs


def embed(params, tokens, cfg: ModelConfig, rules: ShardingRules,
          *, scale: bool = False):
    x = jnp.take(params["table"], tokens, axis=0)
    if scale:    # gemma multiplies by sqrt(d_model)
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return rules.shard(x, "batch", "seq", "act_d_model")


def unembed(params, x, cfg: ModelConfig, rules: ShardingRules):
    if "unembed" in params:
        logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"])
    else:
        logits = jnp.einsum("bsd,vd->bsv", x, params["table"])
    logits = _softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)
    return rules.shard(logits, "batch", "seq", "vocab")
