"""Model assembler: builds any assigned architecture from its ModelConfig.

Layers are grouped into *stages*: consecutive layers whose per-layer
descriptor cycle repeats are stacked along a leading 'layers' axis and
applied with ``jax.lax.scan`` — compile time and HLO size are independent of
depth (critical for the 126-layer llama3-405b dry-run).  Irregular prefixes/
suffixes (deepseek's dense first layer, gemma3's trailing local layers)
become their own stages.

Per-layer descriptor = (attn_kind, ffn_kind):
  attn_kind: 'global' | 'local' | 'encdec' | 'rwkv' | 'mamba'
  ffn_kind:  'mlp' | 'moe' | None (rwkv/mamba blocks are self-contained)

zamba2: a single *shared* attention+MLP block (one param set) is invoked
after every ``shared_attn_every`` mamba layers — passed to the scan body by
closure, outside the stacked stage params.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models import moe as MOE
from repro.models import rwkv6 as R
from repro.sharding.rules import ShardingRules

Desc = Tuple[str, Optional[str]]


@dataclasses.dataclass(frozen=True)
class Stage:
    cycle: Tuple[Desc, ...]   # block descriptors in one scan step
    n: int                    # number of scan steps
    shared_attn: bool = False  # zamba2: apply the shared block after cycle
    encoder: bool = False


def _layer_descs(cfg: ModelConfig) -> List[Desc]:
    descs: List[Desc] = []
    kinds = cfg.layer_kinds()
    for i, kind in enumerate(kinds):
        if kind in ("rwkv", "mamba"):
            descs.append((kind, None))
        else:
            ffn = "mlp"
            if cfg.moe is not None and i >= cfg.moe.dense_first_n:
                ffn = "moe"
            attn = "encdec" if cfg.is_encdec else kind
            descs.append((attn, ffn))
    return descs


def _group_stages(descs: List[Desc], cycle_len: int,
                  shared_every: int = 0) -> List[Stage]:
    stages: List[Stage] = []
    if shared_every:
        cycle_len = shared_every
    i = 0
    n = len(descs)
    while i < n:
        # try to extend a full-cycle run
        cyc = tuple(descs[i:i + cycle_len])
        runs = 0
        j = i
        while j + cycle_len <= n and tuple(descs[j:j + cycle_len]) == cyc:
            runs += 1
            j += cycle_len
        if runs >= 1 and len(cyc) == cycle_len:
            stages.append(Stage(cyc, runs, shared_attn=bool(shared_every)))
            i = j
        else:
            # remainder: group identical consecutive descriptors
            d0 = descs[i]
            j = i
            while j < n and descs[j] == d0:
                j += 1
            stages.append(Stage((d0,), j - i, shared_attn=False))
            i = j
    return stages


# ---------------------------------------------------------------------------
# Block init / apply dispatch
# ---------------------------------------------------------------------------

def _init_block(key, desc: Desc, cfg: ModelConfig, dtype):
    attn_kind, ffn_kind = desc
    if attn_kind == "rwkv":
        return R.init_rwkv_block(key, cfg, dtype)
    if attn_kind == "mamba":
        return M.init_mamba_block(key, cfg, dtype)
    ks = jax.random.split(key, 4)
    ln1, ln1_s = L.init_rms_norm(cfg.d_model, dtype)
    ln2, ln2_s = L.init_rms_norm(cfg.d_model, dtype)
    attn, attn_s = L.init_attention(ks[0], cfg, dtype)
    params = {"ln1": ln1, "attn": attn, "ln2": ln2}
    specs = {"ln1": ln1_s, "attn": attn_s, "ln2": ln2_s}
    if attn_kind == "encdec":
        lnx, lnx_s = L.init_rms_norm(cfg.d_model, dtype)
        xattn, xattn_s = L.init_attention(ks[1], cfg, dtype, cross=True)
        params.update(ln_x=lnx, xattn=xattn)
        specs.update(ln_x=lnx_s, xattn=xattn_s)
    if ffn_kind == "moe":
        m, m_s = MOE.init_moe(ks[2], cfg, dtype)
        params["moe"] = m
        specs["moe"] = m_s
    else:
        d_ff = cfg.d_ff
        if cfg.moe is not None and cfg.moe.d_ff_dense:
            d_ff = cfg.moe.d_ff_dense
        gated = not cfg.is_encdec           # whisper uses plain GELU MLP
        m, m_s = L.init_mlp(ks[3], cfg.d_model, d_ff, dtype, gated=gated)
        params["mlp"] = m
        specs["mlp"] = m_s
    if cfg.post_norms:
        pn1, pn1_s = L.init_rms_norm(cfg.d_model, dtype)
        pn2, pn2_s = L.init_rms_norm(cfg.d_model, dtype)
        params.update(post_ln1=pn1, post_ln2=pn2)
        specs.update(post_ln1=pn1_s, post_ln2=pn2_s)
    return params, specs


def _apply_block(params, desc: Desc, x, cfg: ModelConfig,
                 rules: ShardingRules, *, positions, cache=None,
                 decode_pos=None, cross_kv=None, causal=True):
    """Returns (x, aux, new_cache)."""
    attn_kind, ffn_kind = desc
    zero = jnp.zeros((), jnp.float32)
    if attn_kind == "rwkv":
        x, nc = R.rwkv_block(params, x, cfg, rules, cache=cache)
        return x, zero, nc
    if attn_kind == "mamba":
        x, nc = M.mamba_block(params, x, cfg, rules, cache=cache)
        return x, zero, nc
    # transformer block
    h = L.rms_norm(x, params["ln1"], cfg.norm_eps)
    self_cache = None if cache is None else cache.get("self")
    a, new_self = L.attention(
        params["attn"], h, positions, rules, cfg,
        kind="local" if attn_kind == "local" else "global",
        cache=self_cache, decode_pos=decode_pos, causal=causal,
        rope=cfg.attn.rope)
    if cfg.post_norms:
        a = L.rms_norm(a, params["post_ln1"], cfg.norm_eps)
    x = x + a
    new_cache = None
    if attn_kind == "encdec":
        hx = L.rms_norm(x, params["ln_x"], cfg.norm_eps)
        if cache is not None and "ck" in cache:
            # decode: cached cross K/V
            xa = _cached_cross_attention(params["xattn"], hx, cache, cfg,
                                         rules)
        else:
            xa, _ = L.attention(params["xattn"], hx, positions, rules, cfg,
                                cross_kv=cross_kv, causal=False, rope=False)
        x = x + xa
    h2 = L.rms_norm(x, params["ln2"], cfg.norm_eps)
    aux = zero
    if ffn_kind == "moe":
        f, aux = MOE.moe_ffn(params["moe"], h2, cfg, rules)
    else:
        f = L.mlp(params["mlp"], h2, cfg.act, rules)
    if cfg.post_norms:
        f = L.rms_norm(f, params["post_ln2"], cfg.norm_eps)
    x = x + f
    if cache is not None:
        new_cache = dict(cache)
        if new_self is not None:
            new_cache["self"] = new_self
    return x, aux, new_cache


def _cached_cross_attention(params, x, cache, cfg: ModelConfig,
                            rules: ShardingRules):
    """Decode-time cross attention over precomputed encoder K/V."""
    b, s, _ = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    qh = jnp.einsum("bsd,dkgh->bskgh", x, params["wq"])
    if cfg.attn.qkv_bias:
        qh = qh + params["bq"]
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qh, cache["ck"],
                        preferred_element_type=jnp.float32) * hd ** -0.5
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, cache["cv"])
    return jnp.einsum("bskgh,kghd->bsd", out, params["wo"])


def _init_block_cache(desc: Desc, cfg: ModelConfig, batch: int,
                      cache_len: int, dtype, *, frames: int = 0):
    attn_kind, _ = desc
    if attn_kind == "rwkv":
        return R.init_rwkv_cache(cfg, batch, dtype)
    if attn_kind == "mamba":
        return M.init_mamba_cache(cfg, batch, dtype)
    kvh, hd = cfg.num_kv_heads, cfg.head_dim
    rolling = attn_kind == "local" and cfg.attn.window and \
        cfg.attn.window < cache_len
    sc = cfg.attn.window if rolling else cache_len
    cache = {"self": dict(
        k=jnp.zeros((batch, sc, kvh, hd), dtype),
        v=jnp.zeros((batch, sc, kvh, hd), dtype))}
    if attn_kind == "encdec":
        cache["ck"] = jnp.zeros((batch, frames, kvh, hd), dtype)
        cache["cv"] = jnp.zeros((batch, frames, kvh, hd), dtype)
    return cache


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

class Model:
    def __init__(self, cfg: ModelConfig, remat: bool = False,
                 scan_unroll: int | bool = 1):
        self.cfg = cfg
        self.remat = remat
        # scan_unroll=True fully unrolls layer scans — used ONLY by the
        # dry-run's cost-accounting compile (cost_analysis counts a while
        # body once, so the deployable scanned program under-reports FLOPs;
        # the unrolled twin gives the true totals).
        self.scan_unroll = scan_unroll
        descs = _layer_descs(cfg)
        self.stages = _group_stages(descs, len(cfg.attn.pattern),
                                    cfg.shared_attn_every)
        self.encoder_stages: List[Stage] = []
        if cfg.is_encdec:
            enc_desc = [("global", "mlp")] * cfg.encoder_layers
            self.encoder_stages = [
                dataclasses.replace(s, encoder=True)
                for s in _group_stages(enc_desc, 1)]

    # -- init ----------------------------------------------------------------
    def _init_stage(self, key, stage: Stage, dtype):
        """Stacked params: per cycle position, leaves shaped [n, ...]."""
        blocks, specs = [], []
        for j, desc in enumerate(stage.cycle):
            kj = jax.random.fold_in(key, j)
            if stage.n == 1:
                p, s = _init_block(kj, desc, self.cfg, dtype)
                p = jax.tree_util.tree_map(lambda a: a[None], p)
            else:
                keys = jax.random.split(kj, stage.n)
                p = jax.vmap(
                    lambda k, d=desc: _init_block(k, d, self.cfg, dtype)[0]
                )(keys)
                _, s = _init_block(kj, desc, self.cfg, dtype)
            s = jax.tree_util.tree_map(
                lambda ax: ("layers",) + ax, s,
                is_leaf=lambda x: isinstance(x, tuple) and all(
                    a is None or isinstance(a, str) for a in x))
            blocks.append(p)
            specs.append(s)
        return blocks, specs

    def init(self, key) -> Any:
        cfg = self.cfg
        dtype = L.dtype_of(cfg)
        keys = jax.random.split(key, 8)
        emb, emb_s = L.init_embedding(keys[0], cfg, dtype)
        fn, fn_s = L.init_rms_norm(cfg.d_model, dtype)
        params = {"embed": emb, "final_norm": fn}
        self._specs = {"embed": emb_s, "final_norm": fn_s}
        params["stages"] = []
        self._specs["stages"] = []
        for si, stage in enumerate(self.stages):
            p, s = self._init_stage(jax.random.fold_in(keys[1], si), stage,
                                    dtype)
            params["stages"].append(p)
            self._specs["stages"].append(s)
        if cfg.shared_attn_every:
            p, s = _init_block(keys[2], ("global", "mlp"), cfg, dtype)
            params["shared_attn"] = p
            self._specs["shared_attn"] = s
        if cfg.is_encdec:
            params["enc_stages"] = []
            self._specs["enc_stages"] = []
            for si, stage in enumerate(self.encoder_stages):
                p, s = self._init_stage(jax.random.fold_in(keys[3], si),
                                        stage, dtype)
                params["enc_stages"].append(p)
                self._specs["enc_stages"].append(s)
            efn, efn_s = L.init_rms_norm(cfg.d_model, dtype)
            params["enc_final_norm"] = efn
            self._specs["enc_final_norm"] = efn_s
            params["dec_pos"] = jax.random.normal(
                keys[4], (cfg.max_target_positions, cfg.d_model), dtype) * 0.02
            self._specs["dec_pos"] = ("cache_seq", "d_model")
        return params

    def param_specs(self):
        if not hasattr(self, "_specs"):
            # build specs without materializing params
            jax.eval_shape(self.init, jax.random.PRNGKey(0))
        return self._specs

    # -- stage runner ----------------------------------------------------------
    def _run_stage(self, stage: Stage, stage_params, x, rules, *, positions,
                   cache=None, decode_pos=None, cross_kv=None, causal=True):
        cfg = self.cfg
        shared = getattr(self, "_shared_params", None)

        def body(carry, xs):
            h, aux = carry
            blk_params, blk_cache = xs
            new_caches = []
            for j, desc in enumerate(stage.cycle):
                pj = blk_params[j]
                cj = None if blk_cache is None else blk_cache[j]
                h, a, nc = _apply_block(
                    pj, desc, h, cfg, rules, positions=positions,
                    cache=cj, decode_pos=decode_pos, cross_kv=cross_kv,
                    causal=causal)
                aux = aux + a
                new_caches.append(nc)
            if stage.shared_attn and shared is not None:
                h, a, _ = _apply_block(
                    shared, ("global", "mlp"), h, cfg, rules,
                    positions=positions, cache=None, causal=causal)
                aux = aux + a
            if blk_cache is None:
                return (h, aux), None
            return (h, aux), new_caches

        init = (x, jnp.zeros((), jnp.float32))
        if cache is None:
            if self.remat:
                body = jax.checkpoint(body)   # remat each scanned layer group
            (x, aux), _ = jax.lax.scan(body, init, (stage_params, None),
                                       length=stage.n,
                                       unroll=self.scan_unroll)
            return x, aux, None
        (x, aux), new_cache = jax.lax.scan(body, init, (stage_params, cache),
                                           unroll=self.scan_unroll)
        return x, aux, new_cache

    def _run_stage_decode_shared(self, stage, stage_params, x, rules, *,
                                 positions, cache, decode_pos):
        """zamba2 decode: shared attention needs its own KV cache, which is
        per *invocation* (cycle index), carried in cache[-1]."""
        cfg = self.cfg
        shared = self._shared_params

        def body(carry, xs):
            h, aux = carry
            blk_params, blk_cache, shared_cache = xs
            new_caches = []
            for j, desc in enumerate(stage.cycle):
                h, a, nc = _apply_block(
                    blk_params[j], desc, h, cfg, rules, positions=positions,
                    cache=blk_cache[j], decode_pos=decode_pos)
                aux = aux + a
                new_caches.append(nc)
            h, a, nsc = _apply_block(
                shared, ("global", "mlp"), h, cfg, rules,
                positions=positions, cache=shared_cache,
                decode_pos=decode_pos)
            return (h, aux + a), (new_caches, nsc)

        init = (x, jnp.zeros((), jnp.float32))
        blk_cache, shared_cache = cache
        (x, aux), (new_blk, new_shared) = jax.lax.scan(
            body, init, (stage_params, blk_cache, shared_cache),
            unroll=self.scan_unroll)
        return x, aux, (new_blk, new_shared)

    # -- forward (train / prefill) --------------------------------------------
    def apply(self, params, batch, rules: ShardingRules):
        """batch: dict with 'tokens' [B,S] (+ 'positions', 'patch_embeds',
        'patch_positions', 'frames' as the arch requires).
        Returns (logits [B,S,Vpad], aux dict)."""
        cfg = self.cfg
        self._shared_params = params.get("shared_attn")
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = L.embed(params["embed"], tokens, cfg, rules,
                    scale=cfg.embed_scale)
        if cfg.mrope:
            positions = batch["positions"]          # [B, S, 3]
        else:
            positions = batch.get(
                "positions",
                jnp.broadcast_to(jnp.arange(s)[None], (b, s)))
        if "patch_embeds" in batch:                 # VLM stub frontend
            pe = batch["patch_embeds"].astype(x.dtype)
            ppos = batch["patch_positions"]
            x = x.at[jnp.arange(b)[:, None], ppos].set(pe)
        cross_kv = None
        if cfg.is_encdec:
            frames = batch["frames"]                # [B, F, d] stub embeds
            cross_kv = self._encode(params, frames, rules)
            x = x + params["dec_pos"][None, :s].astype(x.dtype)
        x = rules.shard(x, "batch", "seq", "act_d_model")
        aux = jnp.zeros((), jnp.float32)
        for stage, sp in zip(self.stages, params["stages"]):
            x, a, _ = self._run_stage(stage, sp, x, rules,
                                      positions=positions, cross_kv=cross_kv)
            aux = aux + a
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = L.unembed(params["embed"], x, cfg, rules)
        return logits, {"moe_aux": aux}

    def _encode(self, params, frames, rules):
        cfg = self.cfg
        b, f, _ = frames.shape
        pos_table = L.sinusoidal_embedding(f, cfg.d_model)
        x = frames + pos_table[None].astype(frames.dtype)
        x = rules.shard(x, "batch", "seq", "act_d_model")
        positions = jnp.broadcast_to(jnp.arange(f)[None], (b, f))
        for stage, sp in zip(self.encoder_stages, params["enc_stages"]):
            x, _, _ = self._run_stage(stage, sp, x, rules,
                                      positions=positions, causal=False)
        return L.rms_norm(x, params["enc_final_norm"], cfg.norm_eps)

    # -- cache ------------------------------------------------------------------
    def init_cache(self, batch: int, cache_len: int, *, frames: int = 0):
        cfg = self.cfg
        dtype = L.dtype_of(cfg)
        caches = []
        for stage in self.stages:
            def one(desc):
                return _init_block_cache(desc, cfg, batch, cache_len, dtype,
                                         frames=frames)
            blk = [jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a[None], (stage.n,) + a.shape),
                one(desc)) for desc in stage.cycle]
            # strip non-array flags from stacking (rolling handled below)
            if stage.shared_attn:
                sc = jax.tree_util.tree_map(
                    lambda a: jnp.broadcast_to(a[None],
                                               (stage.n,) + a.shape),
                    _init_block_cache(("global", "mlp"), cfg, batch,
                                      cache_len, dtype))
                caches.append((blk, sc))
            else:
                caches.append(blk)
        return caches

    def cache_logical_specs(self):
        """Logical-axis tuples mirroring ``init_cache``'s structure."""
        cfg = self.cfg

        def block_specs(desc):
            attn_kind, _ = desc
            if attn_kind == "rwkv":
                return dict(tmix_x=("layers", "batch", None),
                            cmix_x=("layers", "batch", None),
                            state=("layers", "batch", "state_heads",
                                   None, None))
            if attn_kind == "mamba":
                return dict(conv=("layers", "batch", None, None),
                            state=("layers", "batch", "state_heads",
                                   None, None))
            c = {"self": dict(
                k=("layers", "batch", "cache_seq", "kv_heads", None),
                v=("layers", "batch", "cache_seq", "kv_heads", None))}
            if attn_kind == "encdec":
                c["ck"] = ("layers", "batch", "frames", "kv_heads", None)
                c["cv"] = ("layers", "batch", "frames", "kv_heads", None)
            return c

        specs = []
        for stage in self.stages:
            blk = [block_specs(desc) for desc in stage.cycle]
            if stage.shared_attn:
                specs.append((blk, block_specs(("global", "mlp"))))
            else:
                specs.append(blk)
        return specs

    # -- decode ---------------------------------------------------------------
    def decode_step(self, params, cache, batch, rules: ShardingRules):
        """One-token step.  batch: dict(tokens [B,1], pos [B],
        optional positions [B,1,3] for mrope).
        Returns (logits [B, Vpad], new_cache)."""
        cfg = self.cfg
        self._shared_params = params.get("shared_attn")
        tokens, pos = batch["tokens"], batch["pos"]
        b = tokens.shape[0]
        x = L.embed(params["embed"], tokens, cfg, rules,
                    scale=cfg.embed_scale)
        if cfg.mrope:
            positions = batch["positions"]
        else:
            positions = pos[:, None]
        if cfg.is_encdec:
            x = x + jnp.take(params["dec_pos"], pos, axis=0)[:, None].astype(
                x.dtype)
        aux = jnp.zeros((), jnp.float32)
        new_caches = []
        for stage, sp, sc in zip(self.stages, params["stages"], cache):
            if stage.shared_attn:
                x, a, nc = self._run_stage_decode_shared(
                    stage, sp, x, rules, positions=positions, cache=sc,
                    decode_pos=pos)
            else:
                x, a, nc = self._run_stage(stage, sp, x, rules,
                                           positions=positions, cache=sc,
                                           decode_pos=pos)
            aux = aux + a
            new_caches.append(nc)
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = L.unembed(params["embed"], x, cfg, rules)
        return logits[:, 0], new_caches


def make_model(cfg: ModelConfig, remat: bool = False,
               scan_unroll: int | bool = 1) -> Model:
    return Model(cfg, remat=remat, scan_unroll=scan_unroll)
