"""Mamba2 (SSD) block (zamba2's backbone): gated state-space with per-head
scalar decay, causal depthwise conv frontend, chunked scan via the GLA core.

Mapping onto chunked_gla: per head h in group g,
  k_t = B_t(g) [N],  v_t = dt_t(h) * x_t(h) [P],  q_t = C_t(g) [N],
  log decay = -exp(A_log_h) * dt_t(h)  (scalar per head per step),
  y_t = q_t . S_t + D_h x_t.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.linear_attention import chunked_gla, gla_decode_step
from repro.models.layers import init_rms_norm, rms_norm
from repro.sharding.rules import ShardingRules


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    inner = s.expand * cfg.d_model
    nheads = inner // s.head_dim
    conv_dim = inner + 2 * s.n_groups * s.state_dim
    return inner, nheads, conv_dim


def init_mamba_block(key, cfg: ModelConfig, dtype):
    s = cfg.ssm
    d = cfg.d_model
    inner, nheads, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 4)
    std = d ** -0.5
    norm, norm_s = init_rms_norm(d, dtype)
    gnorm, gnorm_s = init_rms_norm(inner, dtype)
    proj_out = 2 * inner + 2 * s.n_groups * s.state_dim + nheads
    params = {
        "norm": norm,
        "in_proj": jax.random.normal(ks[0], (d, proj_out), dtype) * std,
        "conv_w": jax.random.normal(ks[1], (s.conv_width, conv_dim), dtype)
        * s.conv_width ** -0.5,
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads).astype(jnp.float32)),
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "gnorm": gnorm,
        "out_proj": jax.random.normal(ks[2], (inner, d), dtype)
        * inner ** -0.5,
    }
    specs = {
        "norm": norm_s,
        "in_proj": ("d_model", "inner"),
        "conv_w": (None, "inner"), "conv_b": ("inner",),
        "A_log": ("state_heads",), "D": ("state_heads",),
        "dt_bias": ("state_heads",),
        "gnorm": gnorm_s,
        "out_proj": ("inner", "d_model"),
    }
    return params, specs


def _causal_conv(xbc, conv_w, conv_b, *, conv_state=None):
    """Depthwise causal conv, width W.  xbc: [B, T, C].
    conv_state: [B, W-1, C] trailing inputs from the previous segment.
    Returns (y [B, T, C], new_conv_state)."""
    w = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], w - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    full = jnp.concatenate([pad, xbc], axis=1)               # [B, T+W-1, C]
    y = sum(full[:, i:i + xbc.shape[1]] * conv_w[i] for i in range(w))
    y = y + conv_b
    new_state = full[:, -(w - 1):]
    return jax.nn.silu(y), new_state


def mamba_block(params, x, cfg: ModelConfig, rules: ShardingRules,
                *, cache=None):
    """x: [B, T, D].  cache: dict(conv [B, W-1, C], state [B, H, N, P]) for
    decode; None for full sequence.  Returns (out, new_cache)."""
    s = cfg.ssm
    inner, nheads, conv_dim = _dims(cfg)
    b, t, d = x.shape
    res = x
    x = rms_norm(x, params["norm"], cfg.norm_eps)
    zxbcdt = jnp.einsum("btd,dp->btp", x, params["in_proj"])
    z, xbc, dt = jnp.split(zxbcdt, [inner, inner + conv_dim], axis=-1)
    xbc, new_conv = _causal_conv(
        xbc, params["conv_w"], params["conv_b"],
        conv_state=None if cache is None else cache["conv"])
    xs, bs, cs = jnp.split(xbc, [inner, inner + s.n_groups * s.state_dim],
                           axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B,T,H]
    decay = -jnp.exp(params["A_log"])[None, None] * dt                # [B,T,H]

    heads_per_group = nheads // s.n_groups
    xh = xs.reshape(b, t, nheads, s.head_dim)
    bh = jnp.repeat(bs.reshape(b, t, s.n_groups, s.state_dim),
                    heads_per_group, axis=2)
    ch = jnp.repeat(cs.reshape(b, t, s.n_groups, s.state_dim),
                    heads_per_group, axis=2)
    to_h = lambda z_: z_.transpose(0, 2, 1, 3)                # [B,H,T,*]
    q = to_h(ch)
    k = to_h(bh)
    v = to_h(xh * dt[..., None].astype(xh.dtype))
    w = decay.transpose(0, 2, 1)[..., None]                   # [B,H,T,1]
    q = rules.shard(q, "batch", "state_heads", "seq", None)
    if cache is not None:
        y, new_state = gla_decode_step(q[:, :, 0], k[:, :, 0], v[:, :, 0],
                                       w[:, :, 0], cache["state"],
                                       include_current=True)
        y = y[:, :, None, :]
    else:
        y, new_state = chunked_gla(q, k, v, w, chunk=min(s.chunk, t),
                                   include_current=True)
    y = y.transpose(0, 2, 1, 3).reshape(b, t, inner).astype(x.dtype)
    y = y + xs * jnp.repeat(params["D"], s.head_dim)[None, None].astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["gnorm"], cfg.norm_eps)
    out = jnp.einsum("bti,id->btd", y, params["out_proj"])
    out = rules.shard(out, "batch", "seq", "act_d_model")
    new_cache = None
    if cache is not None:
        new_cache = dict(conv=new_conv, state=new_state)
    return res + out, new_cache


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype):
    s = cfg.ssm
    inner, nheads, conv_dim = _dims(cfg)
    return dict(
        conv=jnp.zeros((batch, s.conv_width - 1, conv_dim), dtype),
        state=jnp.zeros((batch, nheads, s.state_dim, s.head_dim),
                        jnp.float32),
    )
