from repro.models.config import (  # noqa: F401
    AttnSpec, ModelConfig, MoESpec, RWKVSpec, SSMSpec,
)
# model re-export added once model.py exists
