"""Chunked gated linear attention — shared recurrence core for RWKV6 (Finch,
per-channel data-dependent decay) and Mamba2 (SSD, per-head scalar decay).

Recurrence (state S in R^{Dk x Dv}, decay applied before the token enters):
    S_t = diag(exp(w_t)) S_{t-1} + k_t v_t^T
    y_t = q_t S_t                      (include_current=True, Mamba2)
    y_t = q_t S_{t-1} + (q_t . (u*k_t)) v_t
                                       (include_current=False + bonus u, RWKV6)

Chunked parallel form (the TPU-native adaptation of the GPU recurrent
kernels): with L_t = cumsum of w inside a chunk,
    inter:  y_t += (q_t * exp(Lq_t)) @ S_in
    intra:  A[t,s] = sum_d q_td k_sd exp(Lq_td - L_sd)   (masked s<t or s<=t)
    state:  S_out = exp(L_last)*S_in + sum_s (k_s exp(L_last - L_s)) v_s^T
where Lq_t = L_t (Mamba) or L_{t-1} (RWKV).  All exponents in live positions
are <= 0, so the computation is overflow-safe; the masked region is clamped
before the exp.  Scalar decay uses a cheap [L, L] outer form instead of the
[L, L, Dk] per-channel tensor.

MXU view: each chunk is three matmuls (A = QK', Y = AV, state update) — this
is the compute hot loop and the target of the ``gla_chunk`` Pallas kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def chunked_gla(q, k, v, log_decay, *, chunk: int, state=None,
                include_current: bool = True, bonus=None):
    """q, k: [B, H, T, Dk]; v: [B, H, T, Dv];
    log_decay: [B, H, T, Dk] (per-channel) or [B, H, T, 1] (scalar).
    bonus: [H, Dk] current-token bonus (RWKV u) or None.
    Returns (y [B, H, T, Dv], final_state [B, H, Dk, Dv])."""
    b, h, t, dk = q.shape
    dv = v.shape[-1]
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk
    scalar_decay = log_decay.shape[-1] == 1
    f32 = jnp.float32

    qc = q.reshape(b, h, nc, chunk, dk).transpose(2, 0, 1, 3, 4)
    kc = k.reshape(b, h, nc, chunk, dk).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, h, nc, chunk, dv).transpose(2, 0, 1, 3, 4)
    wc = log_decay.reshape(b, h, nc, chunk, -1).transpose(2, 0, 1, 3, 4)

    if state is None:
        state = jnp.zeros((b, h, dk, dv), f32)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool),
                   0 if include_current else -1)

    def body(s_in, xs):
        qi, ki, vi, wi = xs
        qi32, ki32, vi32 = qi.astype(f32), ki.astype(f32), vi.astype(f32)
        lc = jnp.cumsum(wi.astype(f32), axis=2)              # [B,H,L,{Dk|1}]
        lq = lc if include_current else lc - wi.astype(f32)  # Lq_t
        l_last = lc[:, :, -1:, :]                            # [B,H,1,{Dk|1}]

        # inter-chunk
        q_scaled = qi32 * jnp.exp(lq if not scalar_decay else lq)
        if scalar_decay:
            q_scaled = qi32 * jnp.exp(lq)                    # broadcast [.,1]
        y = jnp.einsum("bhld,bhdv->bhlv", q_scaled, s_in)

        # intra-chunk
        if scalar_decay:
            diff = lq[:, :, :, None, 0] - lc[:, :, None, :, 0]   # [B,H,L,L]
            diff = jnp.where(tri[None, None], diff, -jnp.inf)
            a = jnp.einsum("bhld,bhmd->bhlm", qi32, ki32) * jnp.exp(diff)
        else:
            diff = lq[:, :, :, None, :] - lc[:, :, None, :, :]   # [B,H,L,L,Dk]
            diff = jnp.where(tri[None, None, :, :, None], diff, -jnp.inf)
            a = jnp.einsum("bhld,bhmd,bhlmd->bhlm", qi32, ki32,
                           jnp.exp(diff))
        if bonus is not None:
            diag = jnp.einsum("bhld,hd,bhld->bhl",
                              qi32, bonus.astype(f32), ki32)
            a = a + jnp.eye(chunk, dtype=f32)[None, None] * diag[:, :, :, None]
        y = y + jnp.einsum("bhlm,bhmv->bhlv", a, vi32)

        # state update
        k_scaled = ki32 * jnp.exp(l_last - lc)
        s_out = jnp.exp(l_last.transpose(0, 1, 3, 2)
                        if not scalar_decay else l_last[:, :, 0, :, None]) \
            * s_in
        s_out = s_out + jnp.einsum("bhld,bhlv->bhdv", k_scaled, vi32)
        return s_out, y.astype(q.dtype)

    from repro.models import flags
    final_state, ys = jax.lax.scan(body, state, (qc, kc, vc, wc),
                                   unroll=flags.inner_scan_unroll())
    y = ys.transpose(1, 2, 0, 3, 4).reshape(b, h, t, dv)
    return y, final_state


def gla_decode_step(q1, k1, v1, w1, state, *, include_current: bool = True,
                    bonus=None):
    """Single-token recurrence.  q1/k1: [B, H, Dk]; v1: [B, H, Dv];
    w1: [B, H, Dk] or [B, H, 1] log decay; state [B, H, Dk, Dv].
    Returns (y [B, H, Dv], new_state)."""
    f32 = jnp.float32
    q1, k1, v1 = q1.astype(f32), k1.astype(f32), v1.astype(f32)
    decay = jnp.exp(w1.astype(f32))[..., None]               # [B,H,Dk|1,1]
    kv = k1[..., :, None] * v1[..., None, :]                 # [B,H,Dk,Dv]
    if include_current:
        new_state = decay * state + kv
        y = jnp.einsum("bhd,bhdv->bhv", q1, new_state)
    else:
        y = jnp.einsum("bhd,bhdv->bhv", q1, state)
        if bonus is not None:
            y = y + jnp.einsum("bhd,hd,bhd,bhv->bhv", q1,
                               bonus.astype(f32), k1, v1)
        new_state = decay * state + kv
    return y, new_state


def ref_recurrent_gla(q, k, v, log_decay, *, state=None,
                      include_current=True, bonus=None):
    """O(T) reference recurrence (oracle for tests and the Pallas kernel)."""
    b, h, t, dk = q.shape
    dv = v.shape[-1]
    f32 = jnp.float32
    if state is None:
        state = jnp.zeros((b, h, dk, dv), f32)
    ys = []
    for i in range(t):
        y, state = gla_decode_step(
            q[:, :, i], k[:, :, i], v[:, :, i],
            log_decay[:, :, i], state,
            include_current=include_current, bonus=bonus)
        ys.append(y)
    return jnp.stack(ys, axis=2).astype(q.dtype), state
