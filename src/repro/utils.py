"""Shared small utilities (no jax device state at import time)."""
from __future__ import annotations

import base64
import contextlib
import dataclasses
import json
import os
import tempfile
import time
import zlib
from typing import Any, Callable, Iterable

import jax
import numpy as np


class IntegrityError(RuntimeError):
    """A stored or transmitted artifact failed its checksum.

    Raised at every verification boundary (chunk section, spill batch,
    ckpt block, wire frame, manifest) with a message naming the damaged
    artifact — never a silent wrong result."""


def crc32(data, seed: int = 0) -> int:
    """CRC32 of ``data`` (bytes / buffer / ndarray), as unsigned int."""
    if isinstance(data, np.ndarray):
        data = np.ascontiguousarray(data)
    return zlib.crc32(memoryview(data).cast("B"), seed) & 0xFFFFFFFF


def json_crc(obj: Any) -> int:
    """Canonical CRC32 of a JSON-serializable object (sorted keys)."""
    return crc32(json.dumps(obj, sort_keys=True).encode())


def pack_bools(a) -> str:
    """Bool array -> base64 bitmap string (JSON-friendly; the run-log
    representation of a per-op active mask)."""
    a = np.asarray(a, bool)
    return base64.b64encode(np.packbits(a.reshape(-1)).tobytes()).decode(
        "ascii")


def unpack_bools(s: str, shape) -> np.ndarray:
    """Inverse of :func:`pack_bools` for a known shape."""
    raw = np.frombuffer(base64.b64decode(s), np.uint8)
    n = int(np.prod(shape))
    return np.unpackbits(raw, count=n).reshape(shape).astype(bool)


def atomic_write_json(path: str, obj: Any) -> None:
    """Write JSON via tmp-file + rename so a crash mid-write never leaves a
    truncated file behind (the blockstore/chunkstore manifest commit point)."""
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)))
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(obj, f)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.remove(tmp)
        raise


def register_static_dataclass(cls, data_fields: Iterable[str], static_fields: Iterable[str]):
    """Register a dataclass as a pytree with explicit data/static split."""
    jax.tree_util.register_dataclass(
        cls, data_fields=list(data_fields), meta_fields=list(static_fields)
    )
    return cls


def tree_bytes(tree: Any) -> int:
    """Total bytes of all array leaves in a pytree."""
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for leaf in leaves:
        if hasattr(leaf, "nbytes"):
            total += leaf.nbytes
        elif hasattr(leaf, "dtype") and hasattr(leaf, "shape"):
            total += int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
    return total


def tree_params(tree: Any) -> int:
    """Total number of elements of all array leaves in a pytree."""
    return sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(tree)
               if hasattr(l, "shape"))


def token_ctx(lock):
    """Context manager over an optional shared compute token: the lock
    itself when given, a no-op otherwise.

    The parallel dist_ooc executor hands one lock to every CPU-bound burst
    in its worker pipelines (combine, dispatch, wire decode, chunk decode
    — DESIGN.md §8); holding it for a whole work item lets W threads take
    orderly turns at the host CPU instead of convoying on the GIL at every
    small numpy call, while disk waits and queue handoffs stay outside the
    token and genuinely overlap.  Sequential pipelines pass None and pay
    nothing."""
    return lock if lock is not None else contextlib.nullcontext()


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return ceil_div(a, b) * b


class Timer:
    """Wall-clock timer; ``with Timer() as t: ...; t.seconds``."""

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.seconds = time.perf_counter() - self.t0
        return False


def time_fn(fn: Callable[[], Any], warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time of fn() in seconds, blocking on jax arrays."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


@dataclasses.dataclass
class HardwareSpec:
    """Roofline constants for the target chip (TPU v5e by default)."""
    name: str = "tpu_v5e"
    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_bw: float = 819e9               # bytes/s per chip
    ici_bw: float = 50e9                # bytes/s per ICI link
    ici_links: int = 4                  # usable links per chip (2D torus slice)
    hbm_bytes: int = 16 * 2**30         # HBM capacity
    vmem_bytes: int = 128 * 2**20       # VMEM capacity


V5E = HardwareSpec()
