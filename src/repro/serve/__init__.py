from repro.serve.engine import (  # noqa: F401
    make_serve_step, make_prefill_and_decode, greedy_sample, ServeSession,
)
