"""Serving: batched one-token decode steps with KV/state caches + sampling.

``make_serve_step`` is what the decode_* / long_* dry-run cells lower: one
new token for every sequence in the batch against a cache of the assigned
seq_len.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import padded_vocab
from repro.models.model import Model
from repro.sharding.rules import ShardingRules


def greedy_sample(logits, vocab_size: int):
    pv = logits.shape[-1]
    if pv != vocab_size:
        logits = jnp.where(jnp.arange(pv) >= vocab_size, -1e30, logits)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def topk_sample(logits, key, vocab_size: int, k: int = 40,
                temperature: float = 1.0):
    pv = logits.shape[-1]
    logits = jnp.where(jnp.arange(pv) >= vocab_size, -1e30, logits)
    vals, idx = jax.lax.top_k(logits / jnp.maximum(temperature, 1e-6), k)
    choice = jax.random.categorical(key, vals)
    return jnp.take_along_axis(idx, choice[..., None], -1)[..., 0].astype(
        jnp.int32)


def make_serve_step(model: Model, rules: ShardingRules):
    """step(params, cache, batch) -> (next_token [B], new_cache); batch has
    tokens [B,1], pos [B] (+ positions for mrope archs)."""
    cfg = model.cfg

    def step(params, cache, batch):
        logits, new_cache = model.decode_step(params, cache, batch, rules)
        nxt = greedy_sample(logits, cfg.vocab_size)
        return nxt, new_cache

    return step


def make_prefill_and_decode(model: Model, rules: ShardingRules):
    """Returns (prefill, decode) closures for the CPU serving example."""
    cfg = model.cfg

    def prefill(params, batch):
        logits, _ = model.apply(params, batch, rules)
        return greedy_sample(logits[:, -1], cfg.vocab_size)

    return prefill, make_serve_step(model, rules)


class ServeSession:
    """Tiny batched serving loop for the example driver (CPU scale):
    prefill via teacher-forced forward, then greedy decode with the cache."""

    def __init__(self, model: Model, params, rules: ShardingRules,
                 batch: int, cache_len: int):
        self.model, self.params, self.rules = model, params, rules
        frames = model.cfg.max_source_positions if model.cfg.is_encdec else 0
        self.cache = model.init_cache(batch, cache_len, frames=frames)
        self.step_fn = jax.jit(make_serve_step(model, rules))
        self.batch = batch

    def generate(self, prompts: np.ndarray, steps: int) -> np.ndarray:
        """prompts: [B, P] int32.  Feeds prompt tokens one by one (cache
        warm-up), then samples ``steps`` tokens greedily."""
        b, p = prompts.shape
        out = []
        tok = jnp.asarray(prompts[:, :1])
        for i in range(p + steps - 1):
            batch = {"tokens": tok,
                     "pos": jnp.full((b,), i, jnp.int32)}
            if self.model.cfg.mrope:
                pos3 = jnp.full((b, 1, 3), i, jnp.int32)
                batch["positions"] = pos3
            nxt, self.cache = self.step_fn(self.params, self.cache, batch)
            if i + 1 < p:
                tok = jnp.asarray(prompts[:, i + 1:i + 2])
            else:
                tok = nxt[:, None]
                out.append(np.asarray(nxt))
        return np.stack(out, axis=1)
