from repro.ckpt.blockstore import BlockStore, CheckpointManager  # noqa: F401
