"""Persistent copy-on-write checkpointing (paper §3.2, generalized).

DFOGraph's fault tolerance: *never overwrite a data block*; each Process call
redirects writes to new blocks, per-(VertexArray, batch) block locations are
tracked, obsolete blocks are reclaimed by reference counting, and recovery
loses at most one Process call.

Here the same design covers any pytree of arrays (vertex arrays *and* LM
train state):

* arrays are chopped into fixed-size blocks; each block is stored
  **content-addressed** (sha256) — an unchanged block between checkpoints is
  the same file, so a checkpoint writes only what changed (the paper's Fig. 4
  reuse of batch 0's block);
* a checkpoint = a manifest JSON listing, per array, shape/dtype and the
  ordered block hashes; manifests are written atomically (tmp + rename), so
  a crash mid-write leaves the previous checkpoint intact;
* reference counting = block hash reachable from any kept manifest; GC
  removes unreachable blocks when old manifests are pruned (``keep``);
* recovery = load the latest complete manifest (``restore_latest``).

The storage overhead is old block versions + manifests; the computation
overhead is hashing — checkpointing never re-writes unchanged data, matching
the paper's "checkpointing does not increase the amount of I/O" property.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
from typing import Any

import jax
import numpy as np

from repro.utils import IntegrityError, atomic_write_json, json_crc

DEFAULT_BLOCK_BYTES = 1 << 22       # 4 MiB


def _flatten_with_paths(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


class BlockStore:
    """Content-addressed block storage with manifest checkpoints.

    ``keep`` retention semantics (every ``save`` prunes):
      * ``keep >= 1`` — retain the ``keep`` most recent manifests; older
        manifests are deleted and blocks reachable from no retained
        manifest are garbage-collected.
      * ``keep == 0`` — retention disabled: every manifest (and therefore
        every block) is kept forever.  Explicitly *not* "keep nothing":
        a store that deleted its own latest checkpoint could never
        recover, so 0 is reserved for the unbounded mode.
    """

    def __init__(self, root: str, keep: int = 2,
                 block_bytes: int = DEFAULT_BLOCK_BYTES):
        if keep < 0:
            raise ValueError(f"keep must be >= 0 (0 = retain all), got {keep}")
        self.root = root
        self.keep = keep
        self.block_bytes = block_bytes
        os.makedirs(os.path.join(root, "blocks"), exist_ok=True)
        os.makedirs(os.path.join(root, "manifests"), exist_ok=True)

    # -- block level --------------------------------------------------------
    def _block_path(self, digest: str) -> str:
        return os.path.join(self.root, "blocks", digest + ".blk")

    def _put_block(self, data: bytes) -> tuple[str, bool]:
        digest = hashlib.sha256(data).hexdigest()[:32]
        path = self._block_path(digest)
        if os.path.exists(path):
            return digest, False          # COW reuse — no I/O
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path))
        with os.fdopen(fd, "wb") as f:
            f.write(data)
        os.replace(tmp, path)             # atomic
        return digest, True

    def _get_block(self, digest: str) -> bytes:
        path = self._block_path(digest)
        with open(path, "rb") as f:
            data = f.read()
        # Content-addressing doubles as the integrity check: the stored
        # name IS the expected digest, so re-hashing on read detects any
        # flipped byte before it can reach a restore.
        got = hashlib.sha256(data).hexdigest()[:32]
        if got != digest:
            raise IntegrityError(
                f"checkpoint block {path} failed its content hash "
                f"(stored digest {digest}, read {got}) — disk corruption")
        return data

    # -- checkpoint level ----------------------------------------------------
    def save(self, tree: Any, step: int) -> dict:
        """Write a checkpoint; returns stats (blocks written vs reused)."""
        flat = _flatten_with_paths(tree)
        manifest = {"step": step, "arrays": {}}
        written = reused = bytes_written = 0
        for key, arr in flat.items():
            raw = np.ascontiguousarray(arr).tobytes()
            hashes = []
            for off in range(0, max(len(raw), 1), self.block_bytes):
                digest, new = self._put_block(raw[off:off + self.block_bytes])
                hashes.append(digest)
                if new:
                    written += 1
                    bytes_written += min(self.block_bytes, len(raw) - off)
                else:
                    reused += 1
            manifest["arrays"][key] = {
                "shape": list(arr.shape), "dtype": str(arr.dtype),
                "blocks": hashes,
            }
        manifest["crc"] = json_crc({k: v for k, v in manifest.items()
                                    if k != "crc"})
        mpath = os.path.join(self.root, "manifests", f"{step:012d}.json")
        atomic_write_json(mpath, manifest)   # atomic commit point
        self._gc()
        return dict(blocks_written=written, blocks_reused=reused,
                    bytes_written=bytes_written)

    def steps(self) -> list[int]:
        names = os.listdir(os.path.join(self.root, "manifests"))
        return sorted(int(n.split(".")[0]) for n in names
                      if n.endswith(".json"))

    def _load_manifest(self, step: int) -> dict:
        mpath = os.path.join(self.root, "manifests", f"{step:012d}.json")
        with open(mpath) as f:
            manifest = json.load(f)
        want = manifest.get("crc")
        if want is not None:
            got = json_crc({k: v for k, v in manifest.items()
                            if k != "crc"})
            if got != want:
                raise IntegrityError(
                    f"checkpoint manifest {mpath} failed its checksum "
                    f"(stored crc {want}, computed {got})")
        return manifest

    def restore(self, step: int) -> dict[str, np.ndarray]:
        manifest = self._load_manifest(step)
        out = {}
        for key, meta in manifest["arrays"].items():
            raw = b"".join(self._get_block(h) for h in meta["blocks"])
            out[key] = np.frombuffer(
                raw, dtype=np.dtype(meta["dtype"])).reshape(meta["shape"]).copy()
        return out

    def restore_latest(self) -> tuple[int, dict[str, np.ndarray]] | None:
        steps = self.steps()
        if not steps:
            return None
        return steps[-1], self.restore(steps[-1])

    # -- offline scrub --------------------------------------------------------
    def verify(self) -> list[str]:
        """Re-hash every block and re-check every manifest (the fsck
        primitive).  Returns damage descriptions naming each bad file."""
        damage = []
        bdir = os.path.join(self.root, "blocks")
        for name in sorted(os.listdir(bdir)):
            if not name.endswith(".blk"):
                continue
            try:
                self._get_block(name[:-4])
            except IntegrityError as exc:
                damage.append(str(exc))
        for step in self.steps():
            try:
                manifest = self._load_manifest(step)
            except (IntegrityError, json.JSONDecodeError) as exc:
                damage.append(str(exc))
                continue
            for key, meta in manifest["arrays"].items():
                for h in meta["blocks"]:
                    if not os.path.exists(self._block_path(h)):
                        damage.append(
                            f"checkpoint manifest step {step} at "
                            f"{self.root}: array {key!r} references "
                            f"missing block {h}.blk")
        return damage

    # -- reference-counted GC -------------------------------------------------
    def _gc(self) -> None:
        if self.keep == 0:
            return                        # unbounded retention: nothing to do
        steps = self.steps()
        drop = steps[:-self.keep]
        for s in drop:
            os.remove(os.path.join(self.root, "manifests", f"{s:012d}.json"))
        live: set[str] = set()
        for s in self.steps():
            with open(os.path.join(self.root, "manifests",
                                   f"{s:012d}.json")) as f:
                manifest = json.load(f)
            for meta in manifest["arrays"].values():
                live.update(meta["blocks"])
        bdir = os.path.join(self.root, "blocks")
        for name in os.listdir(bdir):
            if name.endswith(".blk") and name[:-4] not in live:
                os.remove(os.path.join(bdir, name))


class CheckpointManager:
    """Train-loop facade: unflattens restored arrays back into a pytree."""

    def __init__(self, root: str, keep: int = 2,
                 block_bytes: int = DEFAULT_BLOCK_BYTES):
        self.store = BlockStore(root, keep=keep, block_bytes=block_bytes)

    def save(self, state: Any, step: int) -> dict:
        return self.store.save(state, step)

    def restore_into(self, template: Any) -> tuple[int, Any] | None:
        """Restore the latest checkpoint shaped like ``template`` (a pytree
        of arrays or ShapeDtypeStructs); returns (step, state) or None."""
        got = self.store.restore_latest()
        if got is None:
            return None
        step, flat = got
        tpl_flat = _flatten_with_paths(template)
        missing = set(tpl_flat) - set(flat)
        if missing:
            raise ValueError(f"checkpoint missing arrays: {sorted(missing)[:5]}")
        leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(template)
        new_leaves = []
        for path, leaf in leaves_with_paths:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            arr = flat[key]
            new_leaves.append(arr.astype(leaf.dtype).reshape(leaf.shape))
        return step, jax.tree_util.tree_unflatten(treedef, new_leaves)
