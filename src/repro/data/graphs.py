"""Graph dataset generators (numpy, host side).

The paper evaluates on twitter-2010 / uk-2014 (real) and RMAT-32 / KRON-38
(synthetic, R-MAT [14] and Kronecker [26]).  Real web-scale crawls are not
available offline, so experiments here use R-MAT with the standard
(a,b,c,d) = (0.57, 0.19, 0.19, 0.05) parameters — the same generator family
the paper uses for its largest graphs — plus a uniform Erdos-Renyi-style
generator as a low-skew control.
"""
from __future__ import annotations

import dataclasses
import io
import os
import tempfile

import numpy as np

from repro.utils import IntegrityError, crc32


@dataclasses.dataclass
class GraphData:
    """An edge list with optional per-edge data, vertices are 0..n-1."""
    num_vertices: int
    src: np.ndarray           # int64 [E]
    dst: np.ndarray           # int64 [E]
    data: np.ndarray | None   # float32 [E] or None

    @property
    def num_edges(self) -> int:
        return int(self.src.shape[0])

    def out_degrees(self) -> np.ndarray:
        return np.bincount(self.src, minlength=self.num_vertices)

    def in_degrees(self) -> np.ndarray:
        return np.bincount(self.dst, minlength=self.num_vertices)

    def reversed(self) -> "GraphData":
        """Graph with reversed edges (paper footnote 4: for 'reverse' messages)."""
        return GraphData(self.num_vertices, self.dst.copy(), self.src.copy(),
                         None if self.data is None else self.data.copy())

    def nbytes(self) -> int:
        """Raw size as (src, dst) pairs, the paper's Table 3 convention."""
        return self.num_edges * 8  # two int32s


def save_edge_list(g: GraphData, path: str) -> int:
    """Serialize a graph as a checksummed npz edge list and return the
    file's CRC32.

    Built once by a run's parent and referenced from the run spec
    (``graph: {"edge_file": path, "crc32": crc}``), so process-mode
    workers can load *arbitrary* graphs — not only ones regenerable from
    RMAT parameters — and verify the bytes before trusting them."""
    buf = io.BytesIO()
    arrays = dict(num_vertices=np.int64(g.num_vertices),
                  src=np.asarray(g.src, np.int64),
                  dst=np.asarray(g.dst, np.int64))
    if g.data is not None:
        arrays["data"] = np.asarray(g.data, np.float32)
    np.savez(buf, **arrays)
    raw = buf.getvalue()
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)))
    with os.fdopen(fd, "wb") as f:
        f.write(raw)
    os.replace(tmp, path)
    return crc32(raw)


def load_edge_list(path: str, expect_crc: int | None = None) -> GraphData:
    """Load a :func:`save_edge_list` file; with ``expect_crc`` the whole
    file is checksummed first and a mismatch raises
    :class:`~repro.utils.IntegrityError` naming the file."""
    with open(path, "rb") as f:
        raw = f.read()
    if expect_crc is not None:
        got = crc32(raw)
        if got != int(expect_crc):
            raise IntegrityError(
                f"edge list {path} failed its checksum (expected "
                f"{int(expect_crc)}, read {got}) — disk corruption")
    with np.load(io.BytesIO(raw), allow_pickle=False) as z:
        data = z["data"] if "data" in z.files else None
        return GraphData(int(z["num_vertices"]), z["src"].copy(),
                         z["dst"].copy(),
                         None if data is None else data.copy())


def rmat_graph(scale: int, edge_factor: int = 16, *, a: float = 0.57,
               b: float = 0.19, c: float = 0.19, seed: int = 0,
               weighted: bool = False, dedup: bool = False) -> GraphData:
    """R-MAT generator (Chakrabarti et al. [14]); 2**scale vertices."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    ab = a + b
    abc = a + b + c
    for bit in range(scale):
        r = rng.random(m)
        right = r > ab                      # column bit set
        bottom = ((r > a) & (r <= ab)) | (r > abc)  # row bit set
        src = (src << 1) | bottom.astype(np.int64)
        dst = (dst << 1) | right.astype(np.int64)
    if dedup:
        key = src * n + dst
        _, idx = np.unique(key, return_index=True)
        src, dst = src[idx], dst[idx]
        m = src.shape[0]
    data = rng.random(m, dtype=np.float32) if weighted else None
    return GraphData(n, src, dst, data)


def uniform_graph(num_vertices: int, num_edges: int, *, seed: int = 0,
                  weighted: bool = False) -> GraphData:
    """Uniform random directed graph (low-skew control)."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, num_vertices, num_edges, dtype=np.int64)
    dst = rng.integers(0, num_vertices, num_edges, dtype=np.int64)
    data = rng.random(num_edges, dtype=np.float32) if weighted else None
    return GraphData(num_vertices, src, dst, data)


def chain_graph(num_vertices: int, *, weighted: bool = False) -> GraphData:
    """Path graph 0 -> 1 -> ... -> n-1 (worst case diameter, like uk-2014's
    ~2500-iteration behaviour in miniature)."""
    src = np.arange(num_vertices - 1, dtype=np.int64)
    dst = src + 1
    data = np.ones(num_vertices - 1, np.float32) if weighted else None
    return GraphData(num_vertices, src, dst, data)


def star_graph(num_vertices: int) -> GraphData:
    """Hub vertex 0 with edges to everyone (max skew)."""
    src = np.zeros(num_vertices - 1, dtype=np.int64)
    dst = np.arange(1, num_vertices, dtype=np.int64)
    return GraphData(num_vertices, src, dst, None)
