from repro.data.graphs import rmat_graph, uniform_graph, GraphData  # noqa: F401
from repro.data.tokens import TokenPipeline, synthetic_token_batches  # noqa: F401
