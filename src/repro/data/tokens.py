"""Synthetic token data pipeline for LM training/serving.

Deterministic, shardable, restartable: batches are a pure function of
(seed, step), so a restarted job resumes mid-epoch with no data loss and a
re-meshed (elastic) job keeps per-example determinism — each global example
index always maps to the same tokens.  This is the data-pipeline analogue of
the paper's "lose at most one Process call" recovery contract.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    """Stateless token-batch source.

    Produces (tokens, targets) of shape [global_batch, seq_len] from a
    counting-based PRNG keyed by (seed, step, example).  Skew-free sharding:
    callers slice rows by data-parallel rank.
    """
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch_at(self, step: int) -> tuple[np.ndarray, np.ndarray]:
        """Materialize the full global batch for ``step`` (host numpy)."""
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, step]))
        toks = rng.integers(0, self.vocab_size,
                            (self.global_batch, self.seq_len + 1),
                            dtype=np.int64)
        # Inject local structure so the loss is learnable (bigram-ish): each
        # token weakly depends on the previous one.
        toks[:, 1:] = (toks[:, 1:] // 2 + toks[:, :-1] // 2) % self.vocab_size
        toks = toks.astype(np.int32)
        return toks[:, :-1], toks[:, 1:]

    def shard_at(self, step: int, rank: int, num_ranks: int):
        """Rows owned by data-parallel ``rank`` at ``step``."""
        tokens, targets = self.batch_at(step)
        rows = self.global_batch // num_ranks
        sl = slice(rank * rows, (rank + 1) * rows)
        return tokens[sl], targets[sl]

    def __iter__(self) -> Iterator[tuple[np.ndarray, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def synthetic_token_batches(vocab_size: int, seq_len: int, global_batch: int,
                            steps: int, seed: int = 0):
    """Finite iterator of ``steps`` global batches."""
    pipe = TokenPipeline(vocab_size, seq_len, global_batch, seed)
    for s in range(steps):
        yield pipe.batch_at(s)
