"""Multi-process dist_ooc worker entrypoint + parent-side launcher
(DESIGN.md §13).

Each rank is a full SPMD engine replica: it rebuilds the graph, the
two-level spec, and the chunk formats deterministically from the run spec,
opens the shared :class:`~repro.core.chunkstore.ShardedChunkStore`
read-only, constructs an Engine carrying a
:class:`~repro.core.transport.ProcContext`, and runs the *same* algorithm
driver as a single-process run — the engine executes only the logical
workers its rank owns, the transport carries the rest.  Every rank writes
a ``result_r{rank}.npz`` with the assembled global values, per-iteration
returns, counters, per-worker totals and the transport's fault/recovery
statistics; live ranks' results are identical, which the fault-injection
tests assert bit-for-bit against a failure-free run.

Run one rank:  ``python -m repro.runtime.procworker <spec.json> <rank>``
Run a fleet:   :func:`launch` (used by tests/test_fault_injection.py).

The run spec is a JSON object::

    {"run_id": str, "world": int, "num_workers": int,
     "rendezvous": dir, "result_dir": dir,
     "graph": {"scale": 7, "edge_factor": 16, "seed": 5, "weighted": true}
              or {"edge_file": path, "crc32": int}  (serialized edge list),
     "spec": {"num_partitions": 4, "batch_size": 16},
     "store_root": sharded-store dir,
     "store_root_rev": optional reversed-graph store dir (wcc),
     "engine": {optional EngineConfig overrides},
     "algorithm": {"name": "pagerank" | "bfs" | "sssp" | "wcc",
                   "args": {...}},
     "fault_plan": FaultPlan.to_json() string or null,
     "io_timeout": seconds, "stall_timeout": seconds,
     "resume": bool  (set by launch(resume=True): restart the whole job
                      from the durable run log + per-op checkpoints)}
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np

FAULT_EXIT = 42     # mirrored from repro.runtime.faults (importable cheaply)


# ---------------------------------------------------------------------------
# Worker side
# ---------------------------------------------------------------------------


def _build_problem(spec: dict):
    """Deterministic per-rank reconstruction of the graph and formats —
    every rank derives bit-identical preprocessing, so the replicas agree
    on specs, need lists, and byte models without shipping arrays."""
    from repro.core import build_dist_graph, build_formats, make_spec
    gsp = spec["graph"]
    if gsp.get("edge_file"):
        # Arbitrary graphs: the parent serialized (and checksummed) the
        # edge list once; every rank loads the identical bytes instead of
        # regenerating from RMAT parameters.
        from repro.data.graphs import load_edge_list
        g = load_edge_list(gsp["edge_file"],
                           expect_crc=gsp.get("crc32"))
    else:
        from repro.data.graphs import rmat_graph
        g = rmat_graph(int(gsp["scale"]), int(gsp.get("edge_factor", 16)),
                       seed=int(gsp.get("seed", 0)),
                       weighted=bool(gsp.get("weighted", False)))
    two = make_spec(g, num_partitions=int(spec["spec"]["num_partitions"]),
                    batch_size=int(spec["spec"]["batch_size"]))
    dg = build_dist_graph(g, two)
    fm = build_formats(dg)
    return g, two, dg, fm


def _run_algorithm(spec: dict, engine, engine_rev):
    from repro.core import algorithms as alg
    name = spec["algorithm"]["name"]
    args = spec["algorithm"].get("args", {})
    if name == "pagerank":
        return alg.pagerank(engine, int(args.get("num_iters", 3)))
    if name == "bfs":
        return alg.bfs(engine, int(args["source"]))
    if name == "sssp":
        return alg.sssp(engine, int(args["source"]))
    if name == "wcc":
        if engine_rev is None:
            raise ValueError("wcc needs store_root_rev in the run spec")
        return alg.wcc(engine, engine_rev)
    raise ValueError(f"unknown algorithm {name!r}")


def _assemble_values(ctx, two, worker_of, values) -> np.ndarray:
    """Each rank's gathered values are authoritative only on its owned
    partitions (process-mode states are padded with zeros elsewhere);
    overlay per partition from its owner's vector."""
    mine = np.asarray(values)
    vecs = ctx.allgather(mine)
    bounds = np.asarray(two.boundaries)
    full = np.zeros_like(mine)
    for p in range(two.num_partitions):
        r = ctx.assign[int(worker_of[p])]
        full[bounds[p]:bounds[p + 1]] = vecs[r][bounds[p]:bounds[p + 1]]
    return full


def worker_main(spec_path: str, rank: int) -> None:
    with open(spec_path) as f:
        spec = json.load(f)
    from repro.core import Engine, EngineConfig
    from repro.core.chunkstore import ShardedChunkStore
    from repro.core.transport import ProcContext
    from repro.runtime.faults import FaultInjector, FaultPlan

    g, two, dg, fm = _build_problem(spec)
    store = ShardedChunkStore.open(spec["store_root"])

    injector = None
    if spec.get("fault_plan"):
        injector = FaultInjector(FaultPlan.from_json(spec["fault_plan"]),
                                 rank)
    ctx = ProcContext(rank, int(spec["world"]), int(spec["num_workers"]),
                      spec["rendezvous"], run_id=spec.get("run_id", "run"),
                      injector=injector,
                      io_timeout=float(spec.get("io_timeout", 120.0)),
                      stall_timeout=float(spec.get("stall_timeout", 30.0)),
                      log_dir=spec["result_dir"],
                      resume=bool(spec.get("resume", False)))
    cfg = EngineConfig(executor="dist_ooc",
                       num_workers=int(spec["num_workers"]),
                       **spec.get("engine", {}))
    engine = Engine(dg, fm, cfg, store=store, proc_ctx=ctx)
    engine_rev = None
    if spec.get("store_root_rev"):
        from repro.core import build_dist_graph, build_formats
        dg_r = build_dist_graph(g.reversed(), two)
        fm_r = build_formats(dg_r)
        store_r = ShardedChunkStore.open(spec["store_root_rev"])
        engine_rev = Engine(dg_r, fm_r, cfg, store=store_r, proc_ctx=ctx)
    # Whole-job restart: with every engine registered, compute the resume
    # point from the durable run logs and restore the spills to it; the
    # driver below then fast-forwards through the committed ops.
    ctx.prepare_resume()

    values, stats = _run_algorithm(spec, engine, engine_rev)
    full = _assemble_values(ctx, two, store.worker_of, values)

    names = sorted(stats.counters)
    wt = engine.worker_totals
    out = dict(
        values=full,
        iterations=np.int64(stats.iterations),
        rets=np.asarray(stats.per_iter_return, np.float64),
        counter_names=np.asarray(names),
        counter_vals=np.asarray([stats.counters[k] for k in names],
                                np.float64),
        wt_disk=np.asarray([t["disk_bytes"] for t in wt], np.float64),
        wt_net=np.asarray([t["net_bytes"] for t in wt], np.float64),
        wt_edges=np.asarray([t["edges_touched"] for t in wt], np.float64),
        assign=np.asarray(ctx.assign, np.int64),
        epoch=np.int64(ctx.epoch),
        recoveries=np.int64(ctx.stats["recoveries"]),
        wire_frames=ctx.stats["wire_frames"],
        dropped=ctx.stats["dropped"],
        redelivered=ctx.stats["redelivered"],
        held=ctx.stats["held"],
        late_delivered=ctx.stats["late_delivered"],
        corrupted=ctx.stats["corrupted"],
        corrupt_frames=ctx.stats["corrupt_frames"],
    )
    os.makedirs(spec["result_dir"], exist_ok=True)
    tmp = os.path.join(spec["result_dir"], f".result_r{rank}.npz.tmp")
    with open(tmp, "wb") as f:
        np.savez(f, **out)
    os.replace(tmp, os.path.join(spec["result_dir"],
                                 f"result_r{rank}.npz"))
    ctx.finalize()


# ---------------------------------------------------------------------------
# Parent side
# ---------------------------------------------------------------------------


def launch(spec: dict, timeout: float = 300.0,
           resume: bool = False) -> list:
    """Spawn one OS process per rank, wait, return the exit codes.

    Writes ``spec.json`` (and per-rank ``log_r{rank}.txt``) under the
    spec's ``result_dir``.  On a hang past ``timeout`` every straggler is
    killed and a RuntimeError names it — a fault-injection run must
    terminate via recovery, never via the parent's watchdog.

    ``resume=True`` restarts a crashed job from its durable run logs +
    per-op checkpoints (same spec, same dirs): the fault plan is stripped
    — the op the crash interrupted was never committed, so a replayed
    plan would re-fire the same kill forever — and the ranks fast-forward
    through every committed op, producing results bit-identical to a
    failure-free run."""
    rdir = spec["result_dir"]
    os.makedirs(rdir, exist_ok=True)
    os.makedirs(spec["rendezvous"], exist_ok=True)
    if resume:
        spec = dict(spec)
        spec["resume"] = True
        spec["fault_plan"] = None
    # Stale port files from a previous (crashed) incarnation would race
    # the fresh rendezvous: a rank could dial a long-gone port.
    for r in range(int(spec["world"])):
        stale = os.path.join(spec["rendezvous"], f"rank{r}.port")
        if os.path.exists(stale):
            os.remove(stale)
    spec_path = os.path.join(rdir, "spec.json")
    with open(spec_path, "w") as f:
        json.dump(spec, f)
    import repro
    # repro may be a namespace package (__file__ is None): locate src/
    # through __path__ so workers can import it regardless
    src_dir = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    env = dict(os.environ)
    env["PYTHONPATH"] = (src_dir + os.pathsep + env["PYTHONPATH"]
                         if env.get("PYTHONPATH") else src_dir)
    procs, logs = [], []
    for r in range(int(spec["world"])):
        log = open(os.path.join(rdir, f"log_r{r}.txt"), "wb")
        logs.append(log)
        procs.append(subprocess.Popen(
            [sys.executable, "-m", "repro.runtime.procworker", spec_path,
             str(r)],
            stdout=log, stderr=subprocess.STDOUT, env=env))
    codes = []
    try:
        for r, p in enumerate(procs):
            try:
                codes.append(p.wait(timeout=timeout))
            except subprocess.TimeoutExpired:
                for q in procs:
                    if q.poll() is None:
                        q.kill()
                raise RuntimeError(
                    f"rank {r} did not finish within {timeout}s "
                    f"(logs under {rdir})")
    finally:
        for log in logs:
            log.close()
    return codes


def load_result(result_dir: str, rank: int) -> dict:
    path = os.path.join(result_dir, f"result_r{rank}.npz")
    with np.load(path, allow_pickle=False) as z:
        return {k: z[k] for k in z.files}


def main(argv) -> int:
    if len(argv) != 3:
        print("usage: python -m repro.runtime.procworker <spec.json> "
              "<rank>", file=sys.stderr)
        return 2
    worker_main(argv[1], int(argv[2]))
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
