from repro.runtime.elastic import plan_elastic_mesh, elastic_restart  # noqa: F401
from repro.runtime.straggler import (  # noqa: F401
    DeferralPolicy, deferred_merge, plan_backup_shards, simulate_round,
)
