"""Straggler mitigation for the filtered push exchange.

DFOGraph's monoid-slot semantics (DESIGN.md §2) make a powerful mitigation
legal: a *slow peer's messages can be deferred to the next round* without
changing the fixpoint — combine(m, defer(m')) == combine(combine(m, m')) for
associative/commutative slots, and the engine's active-set bookkeeping
re-delivers deferred messages.  This module provides:

  * ``deferred_merge`` — functional helper: merge an arrived-mask subset of
    messages now, return the deferred remainder to stage into round t+1;
  * ``DeferralPolicy`` / ``simulate_round`` — deadline-based planning: which
    peers to wait for given per-peer latencies (used by the launcher; here
    validated by simulation since the container has one host);
  * ``plan_backup_shards`` — backup-worker assignment for re-executing the
    slowest shards (classic straggler re-execution, planning only).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DeferralPolicy:
    deadline_factor: float = 2.0    # wait up to factor x median peer latency
    min_peers: float = 0.75         # but never proceed below this fraction


def deferred_merge(recv_msg, recv_mask, arrived_peers):
    """Split a received message block by peer arrival.

    recv_msg/recv_mask: [P, V] (engine phase-2 output);
    arrived_peers: bool [P].
    Returns (now_msg, now_mask, deferred_msg, deferred_mask): the engine
    processes `now` this round; `deferred` is OR-merged into the next
    round's receive buffers (sound for monoid slots)."""
    import jax.numpy as jnp
    a = arrived_peers[:, None]
    now_mask = recv_mask & a
    deferred_mask = recv_mask & ~a
    now_msg = jnp.where(now_mask, recv_msg, 0)
    deferred_msg = jnp.where(deferred_mask, recv_msg, 0)
    return now_msg, now_mask, deferred_msg, deferred_mask


def merge_deferred_entry(monoid_op, mask_now, vals_now, mask_late,
                         vals_late):
    """Combine two receive rows for the same (source partition, dest
    batch): the current round's arrivals with a peer's late (deferred)
    delivery — the host-numpy twin of :func:`deferred_merge`, used by the
    process transport's exchange when a straggler's frames from round t
    are injected into round t+1 (DESIGN.md §13).

    mask_*: bool [v_max]; vals_*: f32 [v_max] (unset rows may hold
    garbage, never read).  Positions present in both merge through
    ``monoid_op`` (np.minimum / np.maximum — associative, commutative,
    idempotent, so late re-delivery cannot change the fixpoint);
    positions present in one pass through untouched.  Returns
    (mask, vals) with vals zeroed outside the mask."""
    both = mask_now & mask_late
    mask = mask_now | mask_late
    vals = np.where(mask_now, vals_now, 0.0).astype(np.float32)
    vals = np.where(mask_late & ~mask_now, vals_late, vals)
    if both.any():
        vals = np.where(both, monoid_op(
            np.asarray(vals_now, np.float32),
            np.asarray(vals_late, np.float32)), vals)
    return mask, vals.astype(np.float32, copy=False)


def simulate_round(latencies: np.ndarray, policy: DeferralPolicy):
    """Given per-peer message latencies for one round, decide the deadline
    and which peers are deferred.  Returns (deadline, arrived_mask,
    makespan_with_deferral, makespan_without)."""
    lat = np.asarray(latencies, np.float64)
    med = np.median(lat)
    deadline = policy.deadline_factor * med
    arrived = lat <= deadline
    if arrived.mean() < policy.min_peers:
        k = int(np.ceil(policy.min_peers * lat.size))
        deadline = np.partition(lat, k - 1)[k - 1]
        arrived = lat <= deadline
    makespan_wait_all = lat.max()
    makespan_deferral = deadline
    return deadline, arrived, makespan_deferral, makespan_wait_all


def plan_backup_shards(shard_times: np.ndarray, num_backups: int):
    """Assign backup workers to the slowest shards (speculative
    re-execution).  Returns indices of shards to replicate."""
    order = np.argsort(np.asarray(shard_times))[::-1]
    return order[:num_backups].copy()


def simulate_training_with_stragglers(step_times: np.ndarray,
                                      policy: DeferralPolicy,
                                      rounds: int = 100,
                                      seed: int = 0):
    """Monte-Carlo the benefit of deferral over synchronous waiting.
    step_times: [P] mean per-peer latencies; heavy-tailed noise added.
    Returns dict(mean_speedup, p99_speedup, deferral_rate)."""
    rng = np.random.default_rng(seed)
    p = step_times.shape[0]
    speedups, deferrals = [], 0
    for _ in range(rounds):
        lat = step_times * rng.lognormal(0.0, 0.5, p)
        # occasional hard straggler
        if rng.random() < 0.3:
            lat[rng.integers(p)] *= 10
        _, arrived, m_def, m_all = simulate_round(lat, policy)
        speedups.append(m_all / max(m_def, 1e-12))
        deferrals += int((~arrived).sum())
    sp = np.asarray(speedups)
    return dict(mean_speedup=float(sp.mean()),
                p99_speedup=float(np.percentile(sp, 99)),
                deferral_rate=deferrals / (rounds * p))
