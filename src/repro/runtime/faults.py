"""Deterministic fault injection for process-mode dist_ooc (DESIGN.md §13).

A :class:`FaultPlan` is a JSON-serializable schedule of failures keyed by
ProcessEdges call index (``pe`` — the engine's ``proc_ctx.pe_seq``, 1-based:
iteration *t* of a driver is its *t*-th ProcessEdges call).  Three kinds:

* ``kill(worker, pe, phase)`` — the rank that *initially* owns logical
  worker ``w`` exits hard (``os._exit(FAULT_EXIT)``) at a defined point of
  that op: ``start`` (before its send tasks), ``send`` (after
  ``after_frames`` socket frames), ``recv`` (before its receive tasks) or
  ``apply`` (after its apply loop, before the final collective).  All four
  points precede the dead rank's contribution to the op's final collective,
  which is what makes rollback-and-replay sufficient (no survivor can have
  committed the op).  The initial-owner guard is what makes replay safe:
  the adopting survivor re-executes the same injection point without
  re-firing it.

* ``drop(src, dst, pe, frame)`` — the ``frame``-th cross-rank frame posted
  from worker ``src`` to worker ``dst`` in that op is silently not sent.
  The receiver's completeness check (posted-matrix vs arrived counts)
  detects the shortfall and the sender's ledger redelivers — byte counters
  are charged once, at post time, so the run stays bit-identical.

* ``delay(worker, pe)`` — every cross-rank frame worker ``w`` posts in
  that op is held past the straggler deadline and delivered at the next
  op's send phase, where the receiver merges it through the slot monoid
  (``straggler.merge_deferred_entry``).  Only monoid-legal for idempotent
  slots (MIN/MAX); :meth:`FaultPlan.validate_for_monoid` rejects ADD.

The injector is consulted only on the socket data path and at the kill
points the executor exposes — a run with an empty plan is byte-for-byte
the plain process-mode run.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading

FAULT_EXIT = 42         # exit code of an injected kill (asserted by tests)

KILL_PHASES = ("start", "send", "recv", "apply")


@dataclasses.dataclass(frozen=True)
class FaultAction:
    kind: str               # "kill" | "drop" | "delay"
    pe: int                 # ProcessEdges call index (1-based)
    worker: int = -1        # kill/delay: acting logical worker
    phase: str = "start"    # kill: one of KILL_PHASES
    after_frames: int = 0   # kill@send: die after this many frames
    src: int = -1           # drop: source worker
    dst: int = -1           # drop: destination worker
    frame: int = 0          # drop: per-(src,dst) frame index in the op


class FaultPlan:
    """An immutable, validated, JSON-round-trippable fault schedule."""

    def __init__(self, actions=()):
        self.actions = tuple(actions)
        for a in self.actions:
            if a.kind not in ("kill", "drop", "delay"):
                raise ValueError(f"unknown fault kind {a.kind!r}")
            if a.pe < 1:
                raise ValueError(
                    f"fault pe index must be >= 1 (1-based ProcessEdges "
                    f"call), got {a.pe}")
            if a.kind == "kill" and a.phase not in KILL_PHASES:
                raise ValueError(
                    f"kill phase must be one of {KILL_PHASES}, got "
                    f"{a.phase!r}")
            if a.kind in ("kill", "delay") and a.worker < 0:
                raise ValueError(f"{a.kind} fault needs a worker")
            if a.kind == "drop" and (a.src < 0 or a.dst < 0):
                raise ValueError("drop fault needs src and dst workers")

    # -- constructors -------------------------------------------------------

    @staticmethod
    def kill(worker: int, pe: int, phase: str = "start",
             after_frames: int = 0) -> "FaultAction":
        return FaultAction("kill", pe, worker=worker, phase=phase,
                           after_frames=after_frames)

    @staticmethod
    def drop(src: int, dst: int, pe: int, frame: int = 0) -> "FaultAction":
        return FaultAction("drop", pe, src=src, dst=dst, frame=frame)

    @staticmethod
    def delay(worker: int, pe: int) -> "FaultAction":
        return FaultAction("delay", pe, worker=worker)

    # -- validation ---------------------------------------------------------

    def has_delay(self) -> bool:
        return any(a.kind == "delay" for a in self.actions)

    def validate_for_monoid(self, monoid_name: str) -> None:
        """Deferred (delayed) delivery re-applies a message after other
        messages already combined — legal only for idempotent monoids.
        ADD would double-count the deferred contribution's interaction
        with the destination's intermediate writes."""
        if self.has_delay() and monoid_name not in ("min", "max"):
            raise ValueError(
                f"delay faults defer message delivery across rounds, "
                f"which is only fixpoint-legal for idempotent monoid "
                f"slots (min/max), not {monoid_name!r}")

    # -- serialization ------------------------------------------------------

    def to_json(self) -> str:
        return json.dumps([dataclasses.asdict(a) for a in self.actions])

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        return cls([FaultAction(**d) for d in json.loads(text)])


class FaultInjector:
    """Per-process realization of a :class:`FaultPlan`.

    Hook points (all no-ops under an empty plan):

    * :meth:`maybe_kill` — executor phase boundaries (start/recv/apply);
    * :meth:`on_frame_sent` — after each socket frame (kill@send);
    * :meth:`should_drop` / :meth:`should_hold` — consulted by
      ``ProcContext.send_data`` per cross-rank frame.

    Kills fire only on the worker's *initial* owner rank (the replaying
    adopter must not re-die), exit via ``os._exit(FAULT_EXIT)`` — no
    cleanup, no flush: the hardest failure the transport can see short of
    a machine loss."""

    def __init__(self, plan: FaultPlan, rank: int):
        self.plan = plan
        self.rank = rank
        self._lock = threading.Lock()
        self._sent: dict = {}       # (pe, src_w) -> frames sent
        self._posted: dict = {}     # (pe, src_w, dst_w) -> frames posted

    def _my_kill(self, ctx, pe: int, phase: str):
        for a in self.plan.actions:
            if (a.kind == "kill" and a.pe == pe and a.phase == phase
                    and ctx.initial_assign[a.worker] == self.rank
                    and ctx.assign[a.worker] == self.rank):
                return a
        return None

    def maybe_kill(self, ctx, phase: str) -> None:
        if self._my_kill(ctx, ctx.pe_seq, phase) is not None:
            os._exit(FAULT_EXIT)

    def on_frame_sent(self, ctx, pe: int, src_w: int) -> None:
        with self._lock:
            n = self._sent[(pe, src_w)] = self._sent.get((pe, src_w),
                                                         0) + 1
        a = self._my_kill(ctx, pe, "send")
        if a is not None and a.worker == src_w and n > a.after_frames:
            os._exit(FAULT_EXIT)

    def should_drop(self, pe: int, src_w: int, dst_w: int) -> bool:
        with self._lock:
            idx = self._posted.get((pe, src_w, dst_w), 0)
            self._posted[(pe, src_w, dst_w)] = idx + 1
        return any(a.kind == "drop" and a.pe == pe and a.src == src_w
                   and a.dst == dst_w and a.frame == idx
                   for a in self.plan.actions)

    def should_hold(self, pe: int, src_w: int) -> bool:
        return any(a.kind == "delay" and a.pe == pe and a.worker == src_w
                   for a in self.plan.actions)
