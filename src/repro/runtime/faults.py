"""Deterministic fault injection for process-mode dist_ooc (DESIGN.md §13).

A :class:`FaultPlan` is a JSON-serializable schedule of failures keyed by
ProcessEdges call index (``pe`` — the engine's ``proc_ctx.pe_seq``, 1-based:
iteration *t* of a driver is its *t*-th ProcessEdges call).  Three kinds:

* ``kill(worker, pe, phase)`` — the rank that *initially* owns logical
  worker ``w`` exits hard (``os._exit(FAULT_EXIT)``) at a defined point of
  that op: ``start`` (before its send tasks), ``send`` (after
  ``after_frames`` socket frames), ``recv`` (before its receive tasks) or
  ``apply`` (after its apply loop, before the final collective).  All four
  points precede the dead rank's contribution to the op's final collective,
  which is what makes rollback-and-replay sufficient (no survivor can have
  committed the op).  The initial-owner guard is what makes replay safe:
  the adopting survivor re-executes the same injection point without
  re-firing it.

* ``drop(src, dst, pe, frame)`` — the ``frame``-th cross-rank frame posted
  from worker ``src`` to worker ``dst`` in that op is silently not sent.
  The receiver's completeness check (posted-matrix vs arrived counts)
  detects the shortfall and the sender's ledger redelivers — byte counters
  are charged once, at post time, so the run stays bit-identical.

* ``delay(worker, pe)`` — every cross-rank frame worker ``w`` posts in
  that op is held past the straggler deadline and delivered at the next
  op's send phase, where the receiver merges it through the slot monoid
  (``straggler.merge_deferred_entry``).  Only monoid-legal for idempotent
  slots (MIN/MAX); :meth:`FaultPlan.validate_for_monoid` rejects ADD.

* ``corrupt(...)`` — flip one byte.  ``target="wire"`` flips a payload
  byte of the ``frame``-th cross-rank frame from ``src`` to ``dst``: the
  receiver's frame CRC rejects it and the ledger redelivers a clean copy
  (byte counters charged once, at post time — bit-identical run).
  ``target="chunk" | "spill" | "ckpt"`` flips a byte of the named on-disk
  artifact of logical worker ``worker`` right before the op's ready
  barrier: the next read of that artifact raises a typed
  ``IntegrityError`` naming the damaged file — never a silently-wrong
  result.

* ``stall(src, dst, pe, frame, seconds)`` — the sender freezes mid-frame
  (half the frame written, the send lock held — heartbeats to that peer
  stall too) for ``seconds``.  A short stall resolves into a clean
  delivery; one past the transport's ``stall_timeout`` trips the
  receiver's stall detector and flows into the normal recovery path.

The injector is consulted only on the socket data path, the pre-barrier
disk hook, and the kill points the executor exposes — a run with an empty
plan is byte-for-byte the plain process-mode run.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading

FAULT_EXIT = 42         # exit code of an injected kill (asserted by tests)

KILL_PHASES = ("start", "send", "recv", "apply")

CORRUPT_TARGETS = ("wire", "chunk", "spill", "ckpt")


def flip_byte(path: str, offset: int | None = None) -> int:
    """XOR one byte of ``path`` with 0xFF (mid-file by default); returns
    the flipped offset.  Shared by the fault injector and the integrity
    tests — the canonical single-byte disk corruption."""
    size = os.path.getsize(path)
    if size == 0:
        raise ValueError(f"cannot corrupt empty file {path}")
    off = size // 2 if offset is None else offset
    with open(path, "r+b") as f:
        f.seek(off)
        b = f.read(1)
        f.seek(off)
        f.write(bytes([b[0] ^ 0xFF]))
    return off


@dataclasses.dataclass(frozen=True)
class FaultAction:
    kind: str               # "kill" | "drop" | "delay" | "corrupt" | "stall"
    pe: int                 # ProcessEdges call index (1-based)
    worker: int = -1        # kill/delay/corrupt-disk: acting logical worker
    phase: str = "start"    # kill: one of KILL_PHASES
    after_frames: int = 0   # kill@send: die after this many frames
    src: int = -1           # drop/corrupt-wire/stall: source worker
    dst: int = -1           # drop/corrupt-wire/stall: destination worker
    frame: int = 0          # per-(src,dst) frame index in the op
    target: str = "wire"    # corrupt: one of CORRUPT_TARGETS
    seconds: float = 0.0    # stall: how long the sender freezes mid-frame


class FaultPlan:
    """An immutable, validated, JSON-round-trippable fault schedule."""

    def __init__(self, actions=()):
        self.actions = tuple(actions)
        for a in self.actions:
            if a.kind not in ("kill", "drop", "delay", "corrupt",
                              "stall"):
                raise ValueError(f"unknown fault kind {a.kind!r}")
            if a.pe < 1:
                raise ValueError(
                    f"fault pe index must be >= 1 (1-based ProcessEdges "
                    f"call), got {a.pe}")
            if a.kind == "kill" and a.phase not in KILL_PHASES:
                raise ValueError(
                    f"kill phase must be one of {KILL_PHASES}, got "
                    f"{a.phase!r}")
            if a.kind in ("kill", "delay") and a.worker < 0:
                raise ValueError(f"{a.kind} fault needs a worker")
            if a.kind == "corrupt":
                if a.target not in CORRUPT_TARGETS:
                    raise ValueError(
                        f"corrupt target must be one of "
                        f"{CORRUPT_TARGETS}, got {a.target!r}")
                if a.target == "wire" and (a.src < 0 or a.dst < 0):
                    raise ValueError(
                        "corrupt(target='wire') fault needs src and dst "
                        "workers")
                if a.target != "wire" and a.worker < 0:
                    raise ValueError(
                        f"corrupt(target={a.target!r}) fault needs a "
                        f"worker")
            if a.kind == "stall":
                if a.src < 0 or a.dst < 0:
                    raise ValueError("stall fault needs src and dst "
                                     "workers")
                if not a.seconds > 0:
                    raise ValueError(
                        f"stall fault needs seconds > 0, got {a.seconds}")
            if a.kind == "drop" and (a.src < 0 or a.dst < 0):
                raise ValueError("drop fault needs src and dst workers")

    # -- constructors -------------------------------------------------------

    @staticmethod
    def kill(worker: int, pe: int, phase: str = "start",
             after_frames: int = 0) -> "FaultAction":
        return FaultAction("kill", pe, worker=worker, phase=phase,
                           after_frames=after_frames)

    @staticmethod
    def drop(src: int, dst: int, pe: int, frame: int = 0) -> "FaultAction":
        return FaultAction("drop", pe, src=src, dst=dst, frame=frame)

    @staticmethod
    def delay(worker: int, pe: int) -> "FaultAction":
        return FaultAction("delay", pe, worker=worker)

    @staticmethod
    def corrupt_wire(src: int, dst: int, pe: int,
                     frame: int = 0) -> "FaultAction":
        return FaultAction("corrupt", pe, src=src, dst=dst, frame=frame,
                           target="wire")

    @staticmethod
    def corrupt_disk(worker: int, pe: int,
                     target: str = "chunk") -> "FaultAction":
        return FaultAction("corrupt", pe, worker=worker, target=target)

    @staticmethod
    def stall(src: int, dst: int, pe: int, seconds: float,
              frame: int = 0) -> "FaultAction":
        return FaultAction("stall", pe, src=src, dst=dst, frame=frame,
                           seconds=float(seconds))

    # -- validation ---------------------------------------------------------

    def has_delay(self) -> bool:
        return any(a.kind == "delay" for a in self.actions)

    def validate_for_monoid(self, monoid_name: str) -> None:
        """Deferred (delayed) delivery re-applies a message after other
        messages already combined — legal only for idempotent monoids.
        ADD would double-count the deferred contribution's interaction
        with the destination's intermediate writes."""
        if self.has_delay() and monoid_name not in ("min", "max"):
            raise ValueError(
                f"delay faults defer message delivery across rounds, "
                f"which is only fixpoint-legal for idempotent monoid "
                f"slots (min/max), not {monoid_name!r}")

    # -- serialization ------------------------------------------------------

    def to_json(self) -> str:
        return json.dumps([dataclasses.asdict(a) for a in self.actions])

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        return cls([FaultAction(**d) for d in json.loads(text)])


class FaultInjector:
    """Per-process realization of a :class:`FaultPlan`.

    Hook points (all no-ops under an empty plan):

    * :meth:`maybe_kill` — executor phase boundaries (start/recv/apply);
    * :meth:`on_frame_sent` — after each socket frame (kill@send);
    * :meth:`data_fault` / :meth:`should_hold` — consulted by
      ``ProcContext.send_data`` per cross-rank frame (drop /
      corrupt-wire / stall);
    * :meth:`maybe_corrupt_disk` — ``ProcContext.recoverable`` before
      each op's ready barrier (corrupt chunk / spill / ckpt).

    Kills fire only on the worker's *initial* owner rank (the replaying
    adopter must not re-die), exit via ``os._exit(FAULT_EXIT)`` — no
    cleanup, no flush: the hardest failure the transport can see short of
    a machine loss."""

    def __init__(self, plan: FaultPlan, rank: int):
        self.plan = plan
        self.rank = rank
        self._lock = threading.Lock()
        self._sent: dict = {}       # (pe, src_w) -> frames sent
        self._posted: dict = {}     # (pe, src_w, dst_w) -> frames posted
        self._disk_fired: set = set()   # corrupt-disk action indices fired

    def _my_kill(self, ctx, pe: int, phase: str):
        for a in self.plan.actions:
            if (a.kind == "kill" and a.pe == pe and a.phase == phase
                    and ctx.initial_assign[a.worker] == self.rank
                    and ctx.assign[a.worker] == self.rank):
                return a
        return None

    def maybe_kill(self, ctx, phase: str) -> None:
        if self._my_kill(ctx, ctx.pe_seq, phase) is not None:
            os._exit(FAULT_EXIT)

    def on_frame_sent(self, ctx, pe: int, src_w: int) -> None:
        with self._lock:
            n = self._sent[(pe, src_w)] = self._sent.get((pe, src_w),
                                                         0) + 1
        a = self._my_kill(ctx, pe, "send")
        if a is not None and a.worker == src_w and n > a.after_frames:
            os._exit(FAULT_EXIT)

    def data_fault(self, pe: int, src_w: int, dst_w: int
                   ) -> tuple | None:
        """Consult (and consume) the per-(pe, src, dst) frame counter:
        returns ``None`` (send normally), ``("drop",)``, ``("corrupt",)``
        or ``("stall", seconds)`` for this frame."""
        with self._lock:
            idx = self._posted.get((pe, src_w, dst_w), 0)
            self._posted[(pe, src_w, dst_w)] = idx + 1
        for a in self.plan.actions:
            if not (a.pe == pe and a.src == src_w and a.dst == dst_w
                    and a.frame == idx):
                continue
            if a.kind == "drop":
                return ("drop",)
            if a.kind == "corrupt" and a.target == "wire":
                return ("corrupt",)
            if a.kind == "stall":
                return ("stall", a.seconds)
        return None

    def should_hold(self, pe: int, src_w: int) -> bool:
        return any(a.kind == "delay" and a.pe == pe and a.worker == src_w
                   for a in self.plan.actions)

    # -- disk corruption ----------------------------------------------------

    def maybe_corrupt_disk(self, ctx, engine) -> None:
        """Flip one byte of a chosen on-disk artifact of a worker this
        rank owns (fires once per action, on the worker's initial owner,
        right before the op's ready barrier): a chunk-shard section, a
        vertex-spill batch, or a checkpoint block.  The next read of the
        artifact then raises the matching :class:`IntegrityError` naming
        the damaged file."""
        for i, a in enumerate(self.plan.actions):
            if (a.kind != "corrupt" or a.target == "wire"
                    or a.pe != ctx.pe_seq):
                continue
            with self._lock:
                if (i in self._disk_fired
                        or ctx.initial_assign[a.worker] != self.rank
                        or ctx.assign[a.worker] != self.rank):
                    continue
                self._disk_fired.add(i)
            flip_byte(self._disk_target(engine, a.worker, a.target))

    @staticmethod
    def _disk_target(engine, w: int, target: str) -> str:
        """Pick the concrete file to damage for worker ``w``."""
        if target == "chunk":
            shard = engine.store.shards[w]
            q = shard.partitions[0]
            return os.path.join(shard.root, f"edges_q{q}.bin")
        if target == "spill":
            spill = engine.spills[w]
            name = sorted(spill.names())[0]
            return spill._path(name)
        if target == "ckpt":
            # damage a block the NEWEST manifest references — the one a
            # rollback of the current (never-committed) op would restore;
            # an unreferenced block would never be read again
            store = engine._proc_ckpt_store(w)
            mdir = os.path.join(store.root, "manifests")
            with open(os.path.join(mdir,
                                   sorted(os.listdir(mdir))[-1])) as f:
                mani = json.load(f)
            arrays = mani["arrays"]
            digest = arrays[sorted(arrays)[0]]["blocks"][0]
            return os.path.join(store.root, "blocks", f"{digest}.blk")
        raise ValueError(f"unknown disk corrupt target {target!r}")
