"""Elastic scaling: re-plan the mesh from the live device set and reshard
the latest checkpoint onto it.

Design for 1000+ nodes: the TP ('model') axis is sacred — losing a chip
inside a model-parallel group invalidates the whole group — so elasticity
shrinks the DP/FSDP ('data' x 'pod') product and idles the remainder of a
partial group.  Checkpoints are stored logically unsharded (content-
addressed blocks, repro.ckpt), so resharding is a device_put under the new
rules: no all-to-all shuffling of old shards, the block store is the
exchange medium.  This mirrors the paper's recovery contract: progress lost
is at most one step, capacity lost is only the failed group.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: tuple
    axis_names: tuple
    used_devices: int
    idle_devices: int
    notes: tuple


def plan_elastic_mesh(available: int, *, model: int = 16,
                      pods: Optional[int] = None) -> MeshPlan:
    """Largest ('data', 'model') (or ('pod','data','model')) mesh with the
    model axis intact that fits in ``available`` devices."""
    if available < model:
        raise ValueError(
            f"cannot keep a {model}-wide model axis with only {available} "
            f"devices")
    notes = []
    if pods and pods > 1:
        data = available // (model * pods)
        if data < 1:
            notes.append(f"pod axis collapsed: {available} devices cannot "
                         f"fill {pods} pods")
            pods = 1
            data = available // model
        shape = (pods, data, model)
        names = ("pod", "data", "model")
    else:
        data = available // model
        shape = (data, model)
        names = ("data", "model")
    used = int(np.prod(shape))
    if used < available:
        notes.append(f"{available - used} devices idle (partial DP group)")
    return MeshPlan(shape, names, used, available - used, tuple(notes))


def plan_worker_recovery(live_ranks: Sequence[int], num_workers: int,
                         prev: Sequence[int]) -> list:
    """Deterministic logical-worker -> physical-rank re-plan after a
    failure (the dist_ooc recovery twin of :func:`plan_elastic_mesh`).

    ``prev[w]`` is the rank that owned logical worker ``w`` before the
    failure; ``live_ranks`` is the agreed post-consensus live set.
    Workers whose rank survived keep their assignment; each orphaned
    worker (ascending w) is adopted by the live rank owning the fewest
    workers, ties to the lowest rank.  Every survivor computes this from
    the agreed live set alone — no coordinator — and all derive the
    identical plan, which is what lets them agree on who re-opens the
    dead rank's chunk shards and spills (DESIGN.md §13).  Logical worker
    count never changes: W keys the wire pricing and the spill layout,
    so recovery moves ownership, not shape."""
    live = sorted({int(r) for r in live_ranks})
    if not live:
        raise ValueError("no live ranks to plan recovery onto")
    assign = [int(prev[w]) for w in range(num_workers)]
    loads = {r: 0 for r in live}
    for r in assign:
        if r in loads:
            loads[r] += 1
    for w in range(num_workers):
        if assign[w] not in loads:
            r = min(live, key=lambda x: (loads[x], x))
            assign[w] = r
            loads[r] += 1
    return assign


def make_mesh_from_plan(plan: MeshPlan, devices: Optional[Sequence] = None):
    devs = list(devices if devices is not None else jax.devices())
    sel = np.asarray(devs[:plan.used_devices]).reshape(plan.shape)
    from jax.sharding import Mesh
    return Mesh(sel, plan.axis_names)


def elastic_restart(ckpt_dir: str, template_state, *, available: int,
                    model_axis: int, rules_factory, devices=None):
    """Restore the latest checkpoint and place it on a freshly planned mesh.

    rules_factory(mesh) -> (ShardingRules, state_shardings pytree).
    Returns (step, state_on_new_mesh, mesh, plan)."""
    from repro.ckpt import CheckpointManager
    mgr = CheckpointManager(ckpt_dir)
    got = mgr.restore_into(template_state)
    if got is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    step, state = got
    plan = plan_elastic_mesh(available, model=model_axis)
    mesh = make_mesh_from_plan(plan, devices)
    rules, state_sh = rules_factory(mesh)
    state = jax.tree_util.tree_map(
        lambda arr, sh: jax.device_put(arr, sh), state, state_sh)
    return step, state, mesh, plan
