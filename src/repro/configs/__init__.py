"""Architecture registry: ``get_config(name)`` / ``get_reduced(name)``."""
from __future__ import annotations

import importlib

from repro.configs.shapes import (  # noqa: F401
    SHAPES, ShapeSpec, batch_specs, cache_specs, cell_applicability,
    concrete_batch,
)

ARCHS = {
    "gemma2-9b": "gemma2_9b",
    "llama3-405b": "llama3_405b",
    "yi-6b": "yi_6b",
    "gemma3-4b": "gemma3_4b",
    "mixtral-8x22b": "mixtral_8x22b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "whisper-medium": "whisper_medium",
    "zamba2-1.2b": "zamba2_1_2b",
}


def _module(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return importlib.import_module(f"repro.configs.{ARCHS[name]}")


def get_config(name: str):
    return _module(name).CONFIG


def get_reduced(name: str):
    return _module(name).REDUCED


def all_arch_names():
    return list(ARCHS)
