"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=102400, MoE 64 routed top-6 + 2 shared, fine-grained; first layer is a
dense FFN (d_ff 10944) [arXiv:2401.06066; hf]
"""
from repro.models.config import AttnSpec, ModelConfig, MoESpec

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=102_400,
    attn=AttnSpec(pattern=("global",), rope_theta=10_000.0),
    moe=MoESpec(num_experts=64, top_k=6, d_expert=1408, num_shared=2,
                dense_first_n=1, d_ff_dense=10944),
    act="silu", tie_embeddings=False, sub_quadratic=False,
)

REDUCED = ModelConfig(
    name="deepseek-moe-16b-reduced", family="moe",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=32, vocab_size=512,
    attn=AttnSpec(pattern=("global",), rope_theta=10_000.0),
    moe=MoESpec(num_experts=8, top_k=2, d_expert=32, num_shared=1,
                dense_first_n=1, d_ff_dense=128),
    act="silu", tie_embeddings=False,
)
