"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144 — 5:1 local:global, 128k context [hf:google/gemma-3; unverified]
"""
from repro.models.config import AttnSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b", family="dense",
    num_layers=34, d_model=2560, num_heads=8, num_kv_heads=4, head_dim=256,
    d_ff=10240, vocab_size=262_144,
    attn=AttnSpec(pattern=("local",) * 5 + ("global",), window=1024,
                  qk_norm=True, rope_theta=1_000_000.0,
                  rope_theta_local=10_000.0),
    post_norms=True, embed_scale=True, act="gelu", tie_embeddings=True,
    sub_quadratic=False,
)

REDUCED = ModelConfig(
    name="gemma3-4b-reduced", family="dense",
    num_layers=8, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
    attn=AttnSpec(pattern=("local",) * 2 + ("global",), window=16,
                  qk_norm=True, rope_theta=1_000_000.0,
                  rope_theta_local=10_000.0),
    post_norms=True, embed_scale=True, act="gelu", tie_embeddings=True,
)
