"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, SWA [arXiv:2401.04088; hf]

long_500k eligible: the assigned config specifies sliding-window attention,
so decode state is a rolling window (sub-quadratic).
"""
from repro.models.config import AttnSpec, ModelConfig, MoESpec

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=32_768,
    attn=AttnSpec(pattern=("local",), window=4096, rope_theta=1_000_000.0),
    moe=MoESpec(num_experts=8, top_k=2, d_expert=16384),
    act="silu", tie_embeddings=False, sub_quadratic=True,
)

REDUCED = ModelConfig(
    name="mixtral-8x22b-reduced", family="moe",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=96, vocab_size=512,
    attn=AttnSpec(pattern=("local",), window=16, rope_theta=1_000_000.0),
    moe=MoESpec(num_experts=4, top_k=2, d_expert=96),
    act="silu", tie_embeddings=False, sub_quadratic=True,
)
