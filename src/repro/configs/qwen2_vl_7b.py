"""qwen2-vl-7b [vlm]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 — M-RoPE, dynamic resolution [arXiv:2409.12191; hf]

Backbone only; the vision tower is a stub — input_specs() provides
precomputed patch embeddings merged into the token sequence.
"""
from repro.models.config import AttnSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4, head_dim=128,
    d_ff=18944, vocab_size=152_064,
    attn=AttnSpec(pattern=("global",), qkv_bias=True,
                  rope_theta=1_000_000.0),
    mrope=True, mrope_sections=(16, 24, 24),
    act="silu", tie_embeddings=False, sub_quadratic=False,
)

REDUCED = ModelConfig(
    name="qwen2-vl-7b-reduced", family="vlm",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
    attn=AttnSpec(pattern=("global",), qkv_bias=True,
                  rope_theta=1_000_000.0),
    mrope=True, mrope_sections=(2, 3, 3),
    act="silu", tie_embeddings=False,
)
