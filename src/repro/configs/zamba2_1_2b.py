"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (GQA kv=32) d_ff=8192,
ssm_state=64 — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242; hf]

One shared attention+MLP block (a single parameter set) is invoked after
every 6 Mamba2 layers (zamba's weight-shared global block).  sub-quadratic
(Mamba2 state is O(1); shared-attn decode is linear in cache) -> long_500k.
"""
from repro.models.config import AttnSpec, ModelConfig, SSMSpec

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=32_000,
    attn=AttnSpec(pattern=("global",), rope_theta=10_000.0),
    ssm=SSMSpec(state_dim=64, head_dim=64, expand=2, conv_width=4, chunk=128),
    shared_attn_every=6,
    act="gelu", tie_embeddings=True, sub_quadratic=True,
)

REDUCED = ModelConfig(
    name="zamba2-1.2b-reduced", family="hybrid",
    num_layers=5, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512,
    attn=AttnSpec(pattern=("global",), rope_theta=10_000.0),
    ssm=SSMSpec(state_dim=16, head_dim=16, expand=2, conv_width=4, chunk=8),
    shared_attn_every=2,
    act="gelu", tie_embeddings=True, sub_quadratic=True,
)
