"""llama3-405b [dense]: 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256 — GQA 128k vocab [arXiv:2407.21783]
"""
from repro.models.config import AttnSpec, ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b", family="dense",
    num_layers=126, d_model=16384, num_heads=128, num_kv_heads=8,
    head_dim=128, d_ff=53248, vocab_size=128_256,
    attn=AttnSpec(pattern=("global",), rope_theta=500_000.0),
    act="silu", tie_embeddings=False, sub_quadratic=False,
)

REDUCED = ModelConfig(
    name="llama3-405b-reduced", family="dense",
    num_layers=3, d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
    d_ff=160, vocab_size=512,
    attn=AttnSpec(pattern=("global",), rope_theta=500_000.0),
    act="silu", tie_embeddings=False,
)
