"""rwkv6-1.6b [ssm]: 24L d_model=2048 (attn-free) d_ff=7168 vocab=65536 —
Finch, data-dependent decay [arXiv:2404.05892]

sub-quadratic: O(1) recurrent state -> runs long_500k.
"""
from repro.models.config import AttnSpec, ModelConfig, RWKVSpec

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32, head_dim=64,
    d_ff=7168, vocab_size=65_536,
    attn=AttnSpec(pattern=("global",)),      # unused (attn-free)
    rwkv=RWKVSpec(head_dim=64, decay_lora=64, gate_lora=32, chunk=128),
    act="silu", tie_embeddings=False, sub_quadratic=True,
)

REDUCED = ModelConfig(
    name="rwkv6-1.6b-reduced", family="ssm",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512,
    attn=AttnSpec(pattern=("global",)),
    rwkv=RWKVSpec(head_dim=16, decay_lora=8, gate_lora=8, chunk=8),
    act="silu", tie_embeddings=False, sub_quadratic=True,
)
