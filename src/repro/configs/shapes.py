"""Assigned input-shape set and per-(arch x shape) input specs.

Every LM arch is paired with four shapes:
    train_4k     seq_len=4096   global_batch=256   (training)
    prefill_32k  seq_len=32768  global_batch=32    (inference prefill)
    decode_32k   seq_len=32768  global_batch=128   (one-token decode step,
                                                    KV/state cache of seq_len)
    long_500k    seq_len=524288 global_batch=1     (long-context decode —
                                                    sub-quadratic archs only)

``input_specs`` returns jax.ShapeDtypeStruct stand-ins — weak-type-correct,
shardable, with no device allocation — for the dry-run (lower + compile).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # 'train' | 'prefill' | 'decode' | 'long_decode'
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "long_decode", 524_288, 1),
}


def cell_applicability(cfg: ModelConfig, shape: ShapeSpec) -> str | None:
    """Returns a skip reason, or None if the (arch, shape) cell runs."""
    if shape.kind == "long_decode" and not cfg.sub_quadratic:
        return ("full-attention arch: long_500k needs sub-quadratic decode "
                "state (see DESIGN.md)")
    if shape.kind in ("decode", "long_decode") and not cfg.has_decode:
        return "arch has no decode step"
    return None


def _struct(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for the *data* inputs of the step.

    train/prefill: full-sequence batch;  decode/long_decode: one-token step
    (the cache is produced separately by ``cache_specs``)."""
    b, s = shape.global_batch, shape.seq_len
    i32, f = jnp.int32, jnp.dtype(cfg.dtype)
    if shape.kind in ("train", "prefill"):
        batch = {"tokens": _struct((b, s), i32)}
        if shape.kind == "train":
            batch["targets"] = _struct((b, s), i32)
        if cfg.mrope:
            batch["positions"] = _struct((b, s, 3), i32)
        if cfg.family == "vlm":
            n_patch = min(s // 4, 1024)
            batch["patch_embeds"] = _struct((b, n_patch, cfg.d_model), f)
            batch["patch_positions"] = _struct((b, n_patch), i32)
        if cfg.is_encdec:
            # audio stub frontend: precomputed frame embeddings; the decoder
            # sequence is seq_len // 4 (4:1 frame-to-token ratio)
            batch["frames"] = _struct((b, s, cfg.d_model), f)
            batch["tokens"] = _struct((b, s // 4), i32)
            if shape.kind == "train":
                batch["targets"] = _struct((b, s // 4), i32)
        return batch
    # decode kinds: one new token
    batch = {"tokens": _struct((b, 1), i32), "pos": _struct((b,), i32)}
    if cfg.mrope:
        batch["positions"] = _struct((b, 1, 3), i32)
    return batch


def cache_specs(model, cfg: ModelConfig, shape: ShapeSpec):
    """ShapeDtypeStructs for the decode cache (no allocation)."""
    frames = cfg.max_source_positions if cfg.is_encdec else 0
    return jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                 frames=frames))


def concrete_batch(cfg: ModelConfig, shape: ShapeSpec, seed: int = 0) -> dict:
    """Small concrete batch for smoke tests (host numpy -> jnp)."""
    rng = np.random.default_rng(seed)
    specs = batch_specs(cfg, shape)
    out = {}
    for k, v in specs.items():
        if np.issubdtype(v.dtype, np.integer):
            hi = cfg.vocab_size if "token" in k or "target" in k else \
                max(v.shape[-1] if k == "patch_positions" else shape.seq_len, 2)
            if k == "pos":
                hi = shape.seq_len
            if k == "patch_positions":
                hi = shape.seq_len // 4 if shape.kind == "train" else shape.seq_len
            out[k] = jnp.asarray(
                rng.integers(0, hi, v.shape), v.dtype)
        else:
            out[k] = jnp.asarray(rng.normal(0, 1, v.shape), v.dtype)
    return out
