"""yi-6b [dense]: 32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000 —
llama-arch GQA [arXiv:2403.04652; hf]
"""
from repro.models.config import AttnSpec, ModelConfig

CONFIG = ModelConfig(
    name="yi-6b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=4, head_dim=128,
    d_ff=11008, vocab_size=64_000,
    attn=AttnSpec(pattern=("global",), rope_theta=5_000_000.0),
    act="silu", tie_embeddings=False, sub_quadratic=False,
)

REDUCED = ModelConfig(
    name="yi-6b-reduced", family="dense",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
    attn=AttnSpec(pattern=("global",), rope_theta=5_000_000.0),
    act="silu", tie_embeddings=False,
)
