"""whisper-medium [audio]: 24L d_model=1024 16H (kv=16, i.e. MHA) d_ff=4096
vocab=51865 — enc-dec, conv frontend (stub) [arXiv:2212.04356]

Adaptations (DESIGN.md): the conv/mel frontend is a stub — input_specs()
provides precomputed frame embeddings [B, frames, d_model]; the learned
decoder position table is extended to 32768 (real model: 448) so the
assigned decode_32k stress shape is exercisable; absolute positions, no
RoPE.  long_500k skipped (full-attention decoder).
"""
from repro.models.config import AttnSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="audio",
    num_layers=24, encoder_layers=24,
    d_model=1024, num_heads=16, num_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=51_865,
    attn=AttnSpec(pattern=("global",), rope=False, qkv_bias=True),
    max_source_positions=1500, max_target_positions=32_768,
    act="gelu", tie_embeddings=True, sub_quadratic=False,
)

REDUCED = ModelConfig(
    name="whisper-medium-reduced", family="audio",
    num_layers=2, encoder_layers=2,
    d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512,
    attn=AttnSpec(pattern=("global",), rope=False, qkv_bias=True),
    max_source_positions=32, max_target_positions=64,
    act="gelu", tie_embeddings=True,
)
