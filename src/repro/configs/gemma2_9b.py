"""gemma2-9b [dense]: 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000 — local+global alternating, logit softcap [arXiv:2408.00118; hf]
"""
from repro.models.config import AttnSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b", family="dense",
    num_layers=42, d_model=3584, num_heads=16, num_kv_heads=8, head_dim=256,
    d_ff=14336, vocab_size=256_000,
    attn=AttnSpec(pattern=("local", "global"), window=4096, softcap=50.0,
                  rope_theta=10_000.0),
    final_logit_softcap=30.0, post_norms=True, embed_scale=True,
    act="gelu", tie_embeddings=True, sub_quadratic=False,
)

REDUCED = ModelConfig(
    name="gemma2-9b-reduced", family="dense",
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
    attn=AttnSpec(pattern=("local", "global"), window=16, softcap=50.0,
                  rope_theta=10_000.0),
    final_logit_softcap=30.0, post_norms=True, embed_scale=True,
    act="gelu", tie_embeddings=True,
)
