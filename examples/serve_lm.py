"""Batched serving example: prefill-by-steps + greedy decode with KV/state
caches across three architecture families (attention / SSM / hybrid).

    PYTHONPATH=src python examples/serve_lm.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_reduced  # noqa: E402
from repro.models.model import make_model  # noqa: E402
from repro.serve.engine import ServeSession  # noqa: E402
from repro.sharding.rules import make_rules  # noqa: E402


def main():
    rules = make_rules(None)
    rng = np.random.default_rng(0)
    for arch in ("yi-6b", "rwkv6-1.6b", "zamba2-1.2b"):
        cfg = get_reduced(arch)
        model = make_model(cfg)
        params = model.init(jax.random.PRNGKey(1))
        batch, prompt_len, gen = 4, 8, 12
        session = ServeSession(model, params, rules, batch=batch,
                               cache_len=prompt_len + gen + 1)
        prompts = rng.integers(0, cfg.vocab_size, (batch, prompt_len),
                               dtype=np.int32)
        out = session.generate(prompts, steps=gen)
        assert out.shape == (batch, gen)
        assert (out >= 0).all() and (out < cfg.vocab_size).all()
        print(f"{arch:14s} generated {out.shape}: {out[0].tolist()}")
    print("serve_lm OK")


if __name__ == "__main__":
    main()
