"""The paper's technique as an LM feature: MoE token dispatch as DFOGraph
filtered push (DESIGN.md §3) — run on 8 forced host devices, showing the
expert-parallel all-to-alls in the compiled HLO and the capacity ("need
list") bound in action.

    PYTHONPATH=src python examples/moe_dfo_dispatch.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core.sparse_collectives import (  # noqa: E402
    dense_combine, dense_dispatch, topk_routing,
)


def main():
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    t, d, e, k = 64, 32, 8, 2
    cap = int(1.25 * t * k / e)

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (t, d))
    router = jax.random.normal(jax.random.PRNGKey(1), (d, e)) * 0.3
    w_up = jax.random.normal(jax.random.PRNGKey(2), (e, d, 4 * d)) * d**-0.5
    w_dn = jax.random.normal(jax.random.PRNGKey(3), (e, 4 * d, d)) * (4*d)**-0.5

    def moe(x, router, w_up, w_dn):
        logits = x @ router
        dispatch, idx, pos, wts, _ = topk_routing(logits, k, cap)
        buf = dense_dispatch(x, dispatch, idx, pos, e, cap)     # push
        buf = jax.lax.with_sharding_constraint(
            buf, NamedSharding(mesh, P("model", None, None)))   # EP shards
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_up))
        o = jnp.einsum("ecf,efd->ecd", h, w_dn)
        out = dense_combine(o, dispatch, idx, pos, wts, t)      # pull back
        return out, jnp.sum(dispatch)

    shard = lambda spec: NamedSharding(mesh, spec)
    with mesh:
        jitted = jax.jit(moe, in_shardings=(
            shard(P("data", None)), shard(P(None)),
            shard(P("model", None, None)), shard(P("model", None, None))))
        lowered = jitted.lower(x, router, w_up, w_dn)
        compiled = lowered.compile()
        out, kept = jitted(x, router, w_up, w_dn)

    hlo = compiled.as_text()
    a2a = hlo.count("all-to-all")
    ag = hlo.count("all-gather")
    print(f"tokens={t} experts={e} top_k={k} capacity/expert={cap}")
    print(f"kept (token,choice) pairs: {int(kept)} / {t * k} "
          f"(dropped over capacity = paper's bounded message buffers)")
    print(f"compiled collectives: all-to-all x{a2a}, all-gather x{ag} "
          f"(the DFO inter-node pass on the 'model' axis)")
    print(f"output shape {out.shape}, finite={bool(jnp.isfinite(out).all())}")
    print("moe_dfo_dispatch OK")


if __name__ == "__main__":
    main()
