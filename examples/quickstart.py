"""Quickstart: DFOGraph engine on an R-MAT graph — the paper's PageRank +
SSSP with the signal/slot API, filtering counters, and a checkpoint/restart.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.ckpt import BlockStore  # noqa: E402
from repro.core import Engine, build_dist_graph, build_formats, make_spec  # noqa: E402
from repro.core import algorithms as alg  # noqa: E402
from repro.data.graphs import rmat_graph  # noqa: E402


def main():
    print("== build graph (R-MAT scale 10, edge factor 16) ==")
    g = rmat_graph(10, 16, seed=42, weighted=True)
    print(f"|V|={g.num_vertices}  |E|={g.num_edges}")

    print("== two-level column-oriented partition (P=4, batch=64) ==")
    spec = make_spec(g, num_partitions=4, batch_size=64)
    dg = build_dist_graph(g, spec)
    fm = build_formats(dg)
    print(f"boundaries={spec.boundaries}  batches/partition={spec.num_batches}")
    engine = Engine(dg, fm)

    print("== PageRank (5 iterations) ==")
    pr, stats = alg.pagerank(engine, num_iters=5)
    ref = alg.ref_pagerank(g.num_vertices, g.src, g.dst, 5)
    print(f"max |err| vs oracle: {np.abs(pr - ref).max():.2e}")
    c = stats.counters
    print(f"messages sent: {c['msgs_sent']:.0f} "
          f"(unfiltered would be {c['msgs_sent_nofilter']:.0f} — "
          f"filtering saved "
          f"{100 * (1 - c['msgs_sent'] / c['msgs_sent_nofilter']):.1f}%)")
    print(f"net bytes: {c['net_bytes']:.0f}  edge bytes read: "
          f"{c['edge_read_bytes']:.0f}")

    print("== SSSP with checkpoint/restart (paper §3.2) ==")
    source = int(np.argmax(g.out_degrees()))
    with tempfile.TemporaryDirectory() as d:
        store = BlockStore(d, keep=2)
        # run 3 iterations, checkpoint, 'crash', restore, finish
        state = engine.init_state(
            dist=np.where(np.asarray(engine.global_id) == source,
                          0.0, np.float32(np.finfo(np.float32).max / 4)))
        import jax.numpy as jnp
        active = (engine.global_id == source) & engine.graph.vertex_valid
        for i in range(3):
            state, active, upd, _ = engine.process_edges(
                state,
                signal_fn=lambda s, gid: s["dist"],
                slot_fn=lambda m, d_: m + d_,
                monoid=alg.MIN,
                apply_fn=lambda s, agg, has, gid: (
                    {"dist": jnp.minimum(s["dist"], agg)},
                    has & (agg < s["dist"]),
                    (agg < s["dist"]).astype(jnp.float32)),
                active=active)
        store.save({"dist": np.asarray(state["dist"]),
                    "active": np.asarray(active)}, step=3)
        print("checkpointed at iteration 3; simulating crash + restore...")
        step, restored = store.restore_latest()
        state = engine.init_state(dist=restored["dist"])
        active = jnp.asarray(restored["active"])
        it = step
        while True:
            state, active, upd, _ = engine.process_edges(
                state,
                signal_fn=lambda s, gid: s["dist"],
                slot_fn=lambda m, d_: m + d_,
                monoid=alg.MIN,
                apply_fn=lambda s, agg, has, gid: (
                    {"dist": jnp.minimum(s["dist"], agg)},
                    has & (agg < s["dist"]),
                    (agg < s["dist"]).astype(jnp.float32)),
                active=active)
            it += 1
            if float(upd) == 0:
                break
        from repro.core.partition import gather_vertex_values
        dist = gather_vertex_values(spec, np.asarray(state["dist"]))
        ref_d = alg.ref_sssp(g.num_vertices, g.src, g.dst, g.data, source)
        print(f"resumed at iter 3, converged at iter {it}; "
              f"max |err| vs oracle: {np.abs(dist - ref_d).max():.2e}")
    print("quickstart OK")


if __name__ == "__main__":
    main()
