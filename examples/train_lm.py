"""End-to-end LM training driver: trains a small llama-family model for a
few hundred steps on the synthetic bigram pipeline, with checkpointing and a
mid-run restart, and verifies the loss actually drops.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.ckpt import CheckpointManager  # noqa: E402
from repro.configs import get_reduced  # noqa: E402
from repro.data.tokens import TokenPipeline  # noqa: E402
from repro.models.model import make_model  # noqa: E402
from repro.sharding.rules import make_rules  # noqa: E402
from repro.train.loop import init_train_state, make_train_step  # noqa: E402
from repro.train.optimizer import OptConfig  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="yi-6b")
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    model = make_model(cfg)
    rules = make_rules(None)
    opt = OptConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, opt, rules))
    pipe = TokenPipeline(cfg.vocab_size, seq_len=32, global_batch=8, seed=0)

    state = init_train_state(model, jax.random.PRNGKey(0))
    losses = []
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        half = args.steps // 2
        for i in range(half):
            toks, tgt = pipe.batch_at(i)
            state, m = step_fn(state, {"tokens": jnp.asarray(toks),
                                       "targets": jnp.asarray(tgt)})
            losses.append(float(m["loss"]))
            if (i + 1) % 20 == 0:
                print(f"step {i+1:4d} loss {losses[-1]:.4f}", flush=True)
        mgr.save(jax.tree_util.tree_map(np.asarray, state), step=half)
        print(f"-- checkpoint at step {half}; simulating restart --")

        # restart from scratch, restore, continue
        state2 = init_train_state(model, jax.random.PRNGKey(0))
        got_step, restored = mgr.restore_into(
            jax.tree_util.tree_map(np.asarray, state2))
        state2 = jax.tree_util.tree_map(jnp.asarray, restored)
        for i in range(got_step, args.steps):
            toks, tgt = pipe.batch_at(i)
            state2, m = step_fn(state2, {"tokens": jnp.asarray(toks),
                                         "targets": jnp.asarray(tgt)})
            losses.append(float(m["loss"]))
            if (i + 1) % 20 == 0:
                print(f"step {i+1:4d} loss {losses[-1]:.4f}", flush=True)

    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"mean loss first 10 steps: {first:.4f} -> last 10: {last:.4f}")
    assert last < first - 0.3, "loss did not decrease"
    print("train_lm OK")


if __name__ == "__main__":
    main()
