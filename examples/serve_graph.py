"""Concurrent graph-query serving: Poisson arrivals through a Q-slot
multi-source BFS server (DESIGN.md §11), the graph analogue of the batched
LM serving example (examples/serve_lm.py).

Queries arrive continuously (seeded exponential inter-arrival gaps,
measured in batched iterations), join the in-flight panel at the next
iteration boundary when a slot frees up, and stream their result out the
iteration their own frontier dies — the batch keeps iterating for the
rest.  Every iteration pays ONE union-frontier chunk stream for however
many queries are in flight, so per-query disk traffic collapses as load
rises.

    PYTHONPATH=src python examples/serve_graph.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core import (  # noqa: E402
    ChunkStore, Engine, EngineConfig, GraphServeSession, build_dist_graph,
    build_formats, make_spec,
)
from repro.core import algorithms as alg  # noqa: E402
from repro.data.graphs import rmat_graph  # noqa: E402


def main():
    print("== build graph (R-MAT scale 10, edge factor 16) ==")
    g = rmat_graph(10, 16, seed=42, weighted=True)
    print(f"|V|={g.num_vertices}  |E|={g.num_edges}")

    slots, num_queries, mean_gap = 4, 10, 0.5
    print(f"== disk-backed engine, Q={slots} serving slots ==")
    spec = make_spec(g, num_partitions=4, batch_size=64)
    dg = build_dist_graph(g, spec)
    fm = build_formats(dg)
    rng = np.random.default_rng(7)
    order = np.argsort(-np.asarray(g.out_degrees()))
    sources = [int(v) for v in order[:num_queries]]

    with tempfile.TemporaryDirectory() as root:
        store = ChunkStore.build(dg, fm, os.path.join(root, "store"))
        engine = Engine(dg, fm,
                        EngineConfig(executor="ooc", num_queries=slots),
                        store=store)
        session = GraphServeSession(engine)

        # Poisson process: exponential inter-arrival gaps, in units of
        # batched iterations; a query submitted mid-flight waits in the
        # queue until a slot frees, then joins the next iteration's batch.
        arrive_at = np.cumsum(rng.exponential(mean_gap, num_queries))
        print(f"== serve {num_queries} BFS queries, Poisson arrivals "
              f"(mean gap {mean_gap} iterations) ==")
        results, submitted = [], 0
        while submitted < num_queries or session.in_flight:
            while (submitted < num_queries
                   and arrive_at[submitted] <= session.steps):
                qid = session.submit(sources[submitted])
                print(f"  iter {session.steps:3d}: query {qid} arrives "
                      f"(source={sources[submitted]})")
                submitted += 1
            if session.in_flight:
                done = session.step()
            else:
                session.steps += 1      # idle iteration, nothing in flight
                done = []
            for r in done:
                reached = int((r.levels < np.finfo(np.float32).max).sum())
                print(f"  iter {session.steps:3d}: query {r.qid} done — "
                      f"wait={r.wait_iters} run={r.run_iters} "
                      f"wall={r.wall_s * 1e3:.0f}ms reached={reached}")
                results.append(r)

        for r in results:
            ref = alg.ref_bfs(g.num_vertices, g.src, g.dst, r.source)
            np.testing.assert_array_equal(r.levels, ref)
        c = session.counters
        disk = (c["measured_edge_read_bytes"]
                + c["measured_vertex_read_bytes"]
                + c["measured_vertex_write_bytes"])
        print(f"served {len(results)} queries in {session.steps} batched "
              f"iterations; measured disk bytes: {disk:.0f} "
              f"({disk / len(results):.0f}/query)  net bytes: "
              f"{c['net_bytes']:.0f}")
    print("serve_graph OK")


if __name__ == "__main__":
    main()
